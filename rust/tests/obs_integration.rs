//! Observability integration: a fully observed harness run must emit a
//! complete per-task stage timeline, both exporters must validate, and —
//! the determinism contract — same-seed runs must export byte-identical
//! files. The chaos scenario additionally has to surface its recovery
//! activity (retry/reroute/degrade spans matching the fault counters).

use std::collections::{HashMap, HashSet};

use surveiledge::config::{Config, Scheme};
use surveiledge::harness::{run_all_schemes, ComputeMode, Harness, RunSpec, SchemeResult};
use surveiledge::obs::{self, Registry, Report, Stage};
use surveiledge::runtime::json::Json;

fn synth() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn chaos_cfg() -> Config {
    let path = format!("{}/configs/chaos.toml", env!("CARGO_MANIFEST_DIR"));
    Config::from_file(std::path::Path::new(&path)).expect("chaos preset")
}

fn observed_run(cfg: &Config, scheme: Scheme) -> (SchemeResult, Registry) {
    let reg = Registry::new();
    let r = Harness::builder(cfg.clone())
        .mode(synth())
        .observe(reg.clone())
        .build()
        .run(scheme)
        .expect("run");
    (r, reg)
}

#[test]
fn same_seed_exports_are_byte_identical() {
    let cfg = chaos_cfg();
    let (_, a) = observed_run(&cfg, Scheme::SurveilEdge);
    let (_, b) = observed_run(&cfg, Scheme::SurveilEdge);
    assert_eq!(a.export_jsonl(), b.export_jsonl(), "JSONL export must be seed-reproducible");
    assert_eq!(
        a.export_prometheus(),
        b.export_prometheus(),
        "Prometheus export must be seed-reproducible"
    );
}

#[test]
fn some_task_traverses_all_seven_pipeline_stages() {
    // An uploaded (doubtful-band) SurveilEdge task touches every pipeline
    // stage: detect → queue → edge_infer → threshold_decide → uplink →
    // queue (cloud) → cloud_infer → verdict.
    let cfg = Config { duration: 120.0, ..Config::single_edge() };
    let (r, reg) = observed_run(&cfg, Scheme::SurveilEdge);
    assert!(r.uploads > 0, "need at least one doubtful-band upload");

    let mut per_task: HashMap<u64, HashSet<Stage>> = HashMap::new();
    for ev in reg.events() {
        per_task.entry(ev.task).or_default().insert(ev.stage);
    }
    let full = per_task
        .values()
        .filter(|stages| Stage::PIPELINE.iter().all(|s| stages.contains(s)))
        .count();
    assert!(
        full > 0,
        "no task covered all {} pipeline stages (of {} tasks with spans)",
        Stage::PIPELINE.len(),
        per_task.len()
    );
}

#[test]
fn exported_metrics_match_scheme_result() {
    let cfg = Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::homogeneous() };
    let (r, reg) = observed_run(&cfg, Scheme::SurveilEdge);
    let sl = [("scheme", "SurveilEdge")];
    assert_eq!(reg.counter("surveiledge_harness_tasks_total", &sl), r.tasks);
    assert_eq!(reg.counter("surveiledge_harness_uploads_total", &sl), r.uploads);
    let hist = reg
        .histogram("surveiledge_stage_seconds", &[("scheme", "SurveilEdge"), ("stage", "verdict")])
        .expect("verdict stage histogram");
    assert_eq!(hist.count(), r.tasks, "one verdict span per task");
}

#[test]
fn chaos_run_fault_spans_match_fault_counters() {
    let cfg = chaos_cfg();
    let (r, reg) = observed_run(&cfg, Scheme::SurveilEdge);
    let mut by_stage: HashMap<Stage, u64> = HashMap::new();
    for ev in reg.events() {
        if ev.stage.is_fault_event() {
            *by_stage.entry(ev.stage).or_default() += 1;
        }
    }
    let retries = by_stage.get(&Stage::Retry).copied().unwrap_or(0);
    let reroutes = by_stage.get(&Stage::Reroute).copied().unwrap_or(0);
    let degrades = by_stage.get(&Stage::Degrade).copied().unwrap_or(0);
    assert_eq!(retries, r.faults.retried, "retry spans vs counter");
    assert_eq!(reroutes, r.faults.rerouted, "reroute spans vs counter");
    assert_eq!(degrades, r.faults.degraded, "degrade spans vs counter");
    assert!(retries + reroutes + degrades > 0, "chaos run produced no recovery spans");
    // The fault plan itself is exported for provenance.
    assert_eq!(reg.gauge("surveiledge_fault_plan_seed", &[("scheme", "SurveilEdge")]), Some(42.0));
}

#[test]
fn exports_pass_their_own_validators() {
    let cfg = chaos_cfg();
    let (_, reg) = observed_run(&cfg, Scheme::SurveilEdge);
    obs::validate_prometheus(&reg.export_prometheus()).expect("prometheus export validates");
    let n = obs::validate_jsonl(&reg.export_jsonl()).expect("jsonl export validates");
    assert_eq!(n, reg.event_count(), "every span round-trips through runtime::json");
    assert!(n > 0);
}

#[test]
fn run_spec_shares_one_registry_across_schemes() {
    let cfg = Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() };
    let reg = Registry::new();
    let spec = RunSpec::new(cfg)
        .schemes(&[Scheme::SurveilEdge, Scheme::CloudOnly])
        .observe(reg.clone());
    let results = run_all_schemes(&spec).expect("run_all_schemes");
    assert_eq!(results.len(), 2);
    for r in &results {
        let sl = [("scheme", r.row.scheme.as_str())];
        assert_eq!(
            reg.counter("surveiledge_harness_tasks_total", &sl),
            r.tasks,
            "per-scheme task counter for {}",
            r.row.scheme
        );
    }

    // The converged report schema round-trips through runtime::json.
    let reports: Vec<Report> = results.iter().map(|r| r.report()).collect();
    let text = obs::reports_to_json(&reports);
    let parsed = Json::parse(&text).expect("report.json parses");
    let arr = parsed.as_arr().expect("array of reports");
    assert_eq!(arr.len(), reports.len());
    for (j, orig) in arr.iter().zip(&reports) {
        let back = Report::from_json(j).expect("report round-trips");
        assert_eq!(back.kind, orig.kind);
        assert_eq!(back.name, orig.name);
        assert_eq!(back.get("tasks"), orig.get("tasks"));
        assert_eq!(back.get("accuracy_f2"), orig.get("accuracy_f2"));
    }
}

#[test]
fn parallel_observed_exports_match_a_sequential_shared_registry() {
    // `run_all_schemes` runs each scheme on its own thread against a
    // private child registry, then folds the children into the shared
    // registry in spec order. The result must be indistinguishable from
    // running the schemes one at a time against a single shared registry:
    // same counters, same gauges, same span stream, byte for byte.
    let cfg = Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() };
    let schemes = [Scheme::SurveilEdge, Scheme::CloudOnly];

    let par_reg = Registry::new();
    run_all_schemes(
        &RunSpec::new(cfg.clone()).schemes(&schemes).observe(par_reg.clone()),
    )
    .expect("parallel observed run");

    let seq_reg = Registry::new();
    for &scheme in &schemes {
        Harness::builder(cfg.clone())
            .mode(synth())
            .observe(seq_reg.clone())
            .build()
            .run(scheme)
            .expect("sequential observed run");
    }

    assert_eq!(par_reg.event_count(), seq_reg.event_count());
    assert_eq!(
        par_reg.export_jsonl(),
        seq_reg.export_jsonl(),
        "span stream diverged between parallel and sequential observation"
    );
    assert_eq!(
        par_reg.export_prometheus(),
        seq_reg.export_prometheus(),
        "metric export diverged between parallel and sequential observation"
    );
}
