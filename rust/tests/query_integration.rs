//! Multi-query frontend integration: work sharing, streaming results,
//! determinism, and retire isolation over the DES substrate.
//!
//! The load-bearing claims (ISSUE acceptance):
//!
//! * K queries over the same cameras run detect + edge classification
//!   exactly **once** per frame — the obs stage counters equal the
//!   single-query (and query-less) counts — while K per-query verdict
//!   streams come out.
//! * Same seed ⇒ byte-identical per-query JSONL exports and identical
//!   per-query reports, in single runs and under `run_all_schemes`.
//! * Retiring a query never perturbs the other queries' streams.

use surveiledge::bus::Broker;
use surveiledge::config::{Config, Scheme};
use surveiledge::harness::{run_all_schemes, ComputeMode, Harness, RunSpec, SchemeResult};
use surveiledge::obs::Registry;
use surveiledge::query::{
    decode_query_verdict, verdicts_jsonl, DeadlineClass, QueryFile, QuerySet, QuerySpec,
};
use surveiledge::types::{CameraId, ClassId};

fn synth() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn cfg() -> Config {
    Config { duration: 60.0, ..Config::single_edge() }
}

/// K same-class standard queries over every camera: work sharing with
/// zero routing/compute perturbation by construction.
fn standard_queries(k: usize) -> QuerySet {
    let specs = (0..k).map(|i| QuerySpec::new(&format!("q{i}"), ClassId::Moped)).collect();
    QuerySet::new(specs).expect("valid specs")
}

fn run_with(queries: Option<QuerySet>, reg: Option<Registry>) -> SchemeResult {
    let mut b = Harness::builder(cfg()).mode(synth());
    if let Some(qs) = queries {
        b = b.queries(qs);
    }
    if let Some(reg) = reg {
        b = b.observe(reg);
    }
    b.build().run(Scheme::SurveilEdge).expect("run")
}

fn stage_count(reg: &Registry, stage: &str) -> u64 {
    reg.histogram("surveiledge_stage_seconds", &[("scheme", "SurveilEdge"), ("stage", stage)])
        .map(|h| h.count())
        .unwrap_or(0)
}

#[test]
fn k_queries_share_one_detect_and_classify_pass() {
    // Baselines: no query set, then one query, then five.
    let mut counts = Vec::new();
    for k in [0usize, 1, 5] {
        let reg = Registry::new();
        let qs = (k > 0).then(|| standard_queries(k));
        let r = run_with(qs, Some(reg.clone()));
        let detect = stage_count(&reg, "detect");
        let edge_infer = stage_count(&reg, "edge_infer");
        assert!(detect > 0, "k={k}: no detections");
        assert!(edge_infer > 0, "k={k}: no edge inference");
        counts.push((detect, edge_infer, r));
    }
    let (d0, e0, _) = &counts[0];
    for (k, (d, e, _)) in [0usize, 1, 5].into_iter().zip(&counts) {
        assert_eq!((d, e), (d0, e0), "k={k}: shared work must not scale with query count");
    }
    // ... while each query still gets its own full verdict stream.
    let (_, _, r5) = &counts[2];
    for i in 0..5 {
        let n = r5.query_verdicts.iter().filter(|v| v.query == format!("q{i}")).count();
        assert!(n > 0, "query q{i} produced no verdicts");
        // Same-class standard queries see identical shared results, so
        // their stream sizes agree.
        let n0 = r5.query_verdicts.iter().filter(|v| v.query == "q0").count();
        assert_eq!(n, n0);
    }
    assert_eq!(r5.per_query.len(), 5);
}

#[test]
fn attaching_standard_queries_leaves_core_pipeline_byte_identical() {
    // Standard-deadline queries weight eq. 7 by exactly 1.0 and share the
    // scenario-class judge draws, so the core run must be unchanged.
    let bare = run_with(None, None);
    let with_queries = run_with(Some(standard_queries(3)), None);
    assert_eq!(bare.tasks, with_queries.tasks);
    assert_eq!(bare.uploads, with_queries.uploads);
    assert_eq!(bare.per_frame, with_queries.per_frame);
    assert_eq!(bare.row.accuracy, with_queries.row.accuracy);
    assert_eq!(bare.row.avg_latency, with_queries.row.avg_latency);
    assert_eq!(bare.row.bandwidth_mb, with_queries.row.bandwidth_mb);
}

#[test]
fn same_seed_runs_export_byte_identical_jsonl_and_reports() {
    let mixed = || {
        let mut a = QuerySpec::new("amber", ClassId::Moped);
        a.deadline = DeadlineClass::Interactive;
        let mut b = QuerySpec::new("persons", ClassId::Person);
        b.cameras = vec![CameraId(0)];
        let mut c = QuerySpec::new("late", ClassId::Car);
        c.until = 30.0;
        QuerySet::new(vec![a, b, c]).unwrap()
    };
    let r1 = run_with(Some(mixed()), None);
    let r2 = run_with(Some(mixed()), None);
    assert!(!r1.query_verdicts.is_empty());
    for id in ["amber", "persons", "late"] {
        assert_eq!(
            verdicts_jsonl(&r1.query_verdicts, id),
            verdicts_jsonl(&r2.query_verdicts, id),
            "{id}: same seed must export byte-identical JSONL"
        );
    }
    assert_eq!(r1.per_query.len(), r2.per_query.len());
    for (a, b) in r1.per_query.iter().zip(&r2.per_query) {
        assert_eq!(a.to_json(), b.to_json());
    }
    // The windowed query stops at its horizon (decisions land at verdict
    // time for tasks captured inside the window, so allow the drain).
    assert!(r1
        .query_verdicts
        .iter()
        .filter(|v| v.query == "late")
        .all(|v| v.t <= 30.0 + 65.0));
}

#[test]
fn run_all_schemes_matches_single_runs_per_query() {
    let qs = standard_queries(2);
    let spec = RunSpec::new(cfg())
        .schemes(&[Scheme::SurveilEdge, Scheme::EdgeOnly])
        .queries(qs.clone());
    let all = run_all_schemes(&spec).expect("run_all_schemes");
    for (scheme, parallel) in [Scheme::SurveilEdge, Scheme::EdgeOnly].into_iter().zip(&all) {
        let mut h = Harness::builder(cfg()).mode(synth()).queries(qs.clone()).build();
        let solo = h.run(scheme).expect("solo run");
        for id in ["q0", "q1"] {
            assert_eq!(
                verdicts_jsonl(&parallel.query_verdicts, id),
                verdicts_jsonl(&solo.query_verdicts, id),
                "{scheme:?}/{id}: parallel and sequential runs must agree"
            );
        }
        for (a, b) in parallel.per_query.iter().zip(&solo.per_query) {
            assert_eq!(a.to_json(), b.to_json());
        }
    }
}

#[test]
fn retiring_a_query_does_not_perturb_survivors() {
    // All standard deadlines (weights stay 1.0) and the retired query's
    // class differs, so survivors' streams must be byte-identical.
    let full = QuerySet::new(vec![
        QuerySpec::new("keep-a", ClassId::Moped),
        QuerySpec::new("gone", ClassId::Person),
        QuerySpec::new("keep-b", ClassId::Car),
    ])
    .unwrap();
    let reduced = QuerySet::new(vec![
        QuerySpec::new("keep-a", ClassId::Moped),
        QuerySpec::new("keep-b", ClassId::Car),
    ])
    .unwrap();
    let before = run_with(Some(full), None);
    let after = run_with(Some(reduced), None);
    for id in ["keep-a", "keep-b"] {
        let a = verdicts_jsonl(&before.query_verdicts, id);
        let b = verdicts_jsonl(&after.query_verdicts, id);
        assert!(!a.is_empty(), "{id} produced no verdicts");
        assert_eq!(a, b, "{id}: retiring \"gone\" must not move this stream");
    }
}

#[test]
fn verdicts_stream_on_per_query_bus_topics() {
    let broker = Broker::new();
    let (rx_q0, _) = broker.subscribe("query/q0/results", 4096);
    let (rx_all, _) = broker.subscribe("query/+/results", 8192);
    let qs = standard_queries(2).with_broker(broker);
    let r = run_with(Some(qs), None);
    let q0_total = r.query_verdicts.iter().filter(|v| v.query == "q0").count();
    let mut streamed = 0usize;
    while let Ok(msg) = rx_q0.try_recv() {
        let v = decode_query_verdict(&msg.payload).expect("decodable verdict frame");
        assert_eq!(v.query, "q0");
        streamed += 1;
    }
    assert_eq!(streamed, q0_total, "every q0 verdict must stream on its topic");
    let mut fanout = 0usize;
    while rx_all.try_recv().is_ok() {
        fanout += 1;
    }
    assert_eq!(fanout, r.query_verdicts.len(), "wildcard sees every query's stream");
}

#[test]
fn shipped_query_preset_parses_and_runs() {
    let path = format!("{}/configs/queries.toml", env!("CARGO_MANIFEST_DIR"));
    let qf = QueryFile::from_file(std::path::Path::new(&path)).expect("preset parses");
    assert_eq!(qf.queries.len(), 3);
    assert!(qf.headroom > 0.0);
    let mut cfg = qf.cfg;
    cfg.duration = 30.0;
    let qs = QuerySet::new(qf.queries).unwrap();
    let r = Harness::builder(cfg)
        .mode(synth())
        .queries(qs)
        .build()
        .run(Scheme::SurveilEdge)
        .expect("preset run");
    assert_eq!(r.per_query.len(), 3);
    assert!(
        r.query_verdicts.iter().any(|v| v.query == "amber-moped"),
        "the all-camera interactive query must produce verdicts"
    );
}
