//! Cross-substrate equivalence (DESIGN.md §4's core promise): the same
//! seeded crops produce identical band decisions and identical threshold
//! trajectories whether they flow through the shared pipeline stage
//! directly (as the DES engine drives it) or through a *live*
//! `EdgeWorker` thread.
//!
//! The mirror side recomputes each step with `classify_stage` + a
//! scripted `PipelineCtx` whose congestion signal is built from the same
//! inputs the live worker reads (cloud backlog x replicated cloud
//! latency; the local-queue term is pinned to zero so the wall-clock
//! estimator cancels out exactly).

use std::sync::{Arc, Mutex};

use surveiledge::bus::Broker;
use surveiledge::config::Scheme;
use surveiledge::faults::HB_STALE_AFTER;
use surveiledge::harness::{
    classify_stage, finetune_corpus, policy_for, EdgeAction, PipelineCtx,
};
use surveiledge::nodes::{controller_for, EdgeWorker, NodeState, RunMetrics};
use surveiledge::paramdb::{ParamDb, Value};
use surveiledge::runtime::service::InferenceService;
use surveiledge::types::{BBox, CameraId, ClassId, Image, NodeId, Task};

const T_CLOUD: f64 = 0.25;

struct Scripted {
    signal: f64,
    cloud_alive: bool,
}

impl PipelineCtx for Scripted {
    fn congestion_signal(&self) -> f64 {
        self.signal
    }
    fn cloud_alive(&self) -> bool {
        self.cloud_alive
    }
}

fn seeded_crops(n: usize, seed: u64) -> Vec<Image> {
    let (pixels, _labels) = finetune_corpus(ClassId::Moped, n, seed);
    let px = 32 * 32 * 3;
    (0..n)
        .map(|k| Image { h: 32, w: 32, data: pixels[k * px..(k + 1) * px].to_vec() })
        .collect()
}

fn task_for(id: u64, crop: Image) -> Task {
    Task {
        id,
        camera: CameraId(0),
        frame_seq: id,
        t_capture: 0.0,
        t_detected: 0.0,
        bbox: BBox { y0: 0, x0: 0, y1: 32, x1: 32 },
        crop,
        truth: None,
    }
}

#[test]
fn live_edge_worker_matches_pipeline_stage_decisions() {
    let svc = match InferenceService::spawn("artifacts".into(), vec![1]) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: inference service unavailable: {e}");
            return;
        }
    };
    let scheme = Scheme::SurveilEdge;
    let broker = Broker::new();
    let db = ParamDb::new();
    let metrics = Arc::new(RunMetrics::default());
    let worker = EdgeWorker {
        state: NodeState::new(NodeId(1), T_CLOUD),
        scheme,
        controller: Mutex::new(controller_for(scheme, 0.1, 0.25, 1.0)),
        service: svc.handle.clone(),
        broker: broker.clone(),
        db: db.clone(),
        metrics: metrics.clone(),
        query: ClassId::Moped,
        slowdown: 1.0,
        queries: None,
        overload: None,
    };
    // Pin the replicated cloud latency: the worker reads t/0 from the DB,
    // the mirror uses the same constant. The worker's own queue stays at
    // zero throughout, so the q·t_local term is exactly 0.0 on both sides
    // even though the live estimator moves with wall time.
    db.put(&ParamDb::key_t(0), Value::F64(T_CLOUD));

    let policy = policy_for(scheme);
    let mut mirror_ctl = controller_for(scheme, 0.1, 0.25, 1.0);
    let mut mirror_backlog = 0u64;

    // Phase 1: cloud alive. Every crop must get the same action and the
    // same (α, β) trajectory on both substrates.
    for (k, crop) in seeded_crops(24, 11).into_iter().enumerate() {
        // Mirror: identical inference call -> identical confidence (the
        // service is deterministic per pixel buffer).
        let probs = svc.handle.edge_infer(1, crop.data.clone()).unwrap();
        let conf = probs.get(1).copied().unwrap_or(0.0);
        let ctx = Scripted { signal: mirror_backlog as f64 * T_CLOUD, cloud_alive: true };
        let outcome = classify_stage(&ctx, policy, &mut mirror_ctl, conf);

        let now = move || 1.0 + k as f64;
        let verdict = worker.classify(task_for(k as u64, crop), &now).unwrap();
        match outcome.action {
            EdgeAction::Verdict { positive } => {
                let v = verdict.expect("stage answered at the edge, live worker must too");
                assert_eq!(v.positive, positive, "verdict sign diverged at task {k}");
            }
            EdgeAction::Upload => {
                assert!(verdict.is_none(), "stage uploaded, live worker must too (task {k})");
                mirror_backlog += 1;
            }
            EdgeAction::Degrade { .. } => unreachable!("cloud is alive in phase 1"),
        }
        let ctl = worker.controller.lock().unwrap();
        assert_eq!(ctl.alpha, mirror_ctl.alpha, "alpha trajectory diverged at task {k}");
        assert_eq!(ctl.beta, mirror_ctl.beta, "beta trajectory diverged at task {k}");
    }
    assert_eq!(
        metrics.cloud_backlog.load(std::sync::atomic::Ordering::Relaxed),
        mirror_backlog,
        "upload accounting diverged"
    );

    // Phase 2: the cloud's heartbeat goes stale — doubtful crops must now
    // degrade to an edge-local verdict on both substrates.
    db.put(&ParamDb::key_hb(0), Value::F64(0.0));
    let stale_now = HB_STALE_AFTER + 1000.0;
    let mut mirror_degrades = 0u64;
    for (k, crop) in seeded_crops(24, 23).into_iter().enumerate() {
        let probs = svc.handle.edge_infer(1, crop.data.clone()).unwrap();
        let conf = probs.get(1).copied().unwrap_or(0.0);
        let ctx = Scripted { signal: mirror_backlog as f64 * T_CLOUD, cloud_alive: false };
        let outcome = classify_stage(&ctx, policy, &mut mirror_ctl, conf);

        let now = move || stale_now;
        let verdict = worker.classify(task_for(100 + k as u64, crop), &now).unwrap();
        match outcome.action {
            EdgeAction::Verdict { positive } | EdgeAction::Degrade { positive } => {
                let v = verdict.expect("dead cloud: the live worker must answer locally");
                assert_eq!(v.positive, positive, "verdict sign diverged at stale task {k}");
                if matches!(outcome.action, EdgeAction::Degrade { .. }) {
                    mirror_degrades += 1;
                }
            }
            EdgeAction::Upload => unreachable!("cloud is dark in phase 2"),
        }
        let ctl = worker.controller.lock().unwrap();
        assert_eq!(ctl.alpha, mirror_ctl.alpha, "alpha trajectory diverged at stale task {k}");
        assert_eq!(ctl.beta, mirror_ctl.beta, "beta trajectory diverged at stale task {k}");
    }
    assert_eq!(
        metrics.degraded.load(std::sync::atomic::Ordering::Relaxed),
        mirror_degrades,
        "degrade accounting diverged"
    );
}
