//! Overload-control integration: the shipped `configs/overload.toml`
//! preset (under-provisioned edge + x3 mid-run burst) must shed batch
//! work first while interactive deadlines stay bounded, account for
//! every task (answered + shed = emitted, zero lost), and reproduce
//! byte-identical exports on same-seed reruns. Runs entirely in
//! simulated time.

use surveiledge::config::Scheme;
use surveiledge::harness::{ComputeMode, Harness, SchemeResult};
use surveiledge::obs::Registry;
use surveiledge::overload::OverloadConfig;
use surveiledge::query::{verdicts_jsonl, QueryFile, QuerySet};

fn synth() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn preset() -> QueryFile {
    let path = format!("{}/configs/overload.toml", env!("CARGO_MANIFEST_DIR"));
    QueryFile::from_file(std::path::Path::new(&path)).expect("overload preset")
}

fn run_preset(reg: Option<Registry>) -> SchemeResult {
    let qf = preset();
    let qs = QuerySet::new(qf.queries).expect("valid specs");
    let mut b = Harness::builder(qf.cfg).mode(synth()).queries(qs);
    if let Some(reg) = reg {
        b = b.observe(reg);
    }
    b.build().run(Scheme::SurveilEdge).expect("run")
}

fn shed_count(r: &SchemeResult, query: &str) -> usize {
    r.query_verdicts.iter().filter(|v| v.query == query && v.site == "shed").count()
}

#[test]
fn shipped_overload_preset_parses() {
    let qf = preset();
    let o = &qf.cfg.overload;
    assert!(o.enabled, "presence of [overload] must enable the subsystem");
    assert!(o.node_queue_cap > 0 && o.uplink_queue_cap > 0);
    assert_eq!(o.burst_factor(70.0), 3, "burst window must cover t=70");
    assert_eq!(o.burst_factor(10.0), 1, "no burst off-window");
    assert_eq!(qf.queries.len(), 2);
    assert_eq!(qf.queries[0].id, "amber-interactive");
    assert_eq!(qf.queries[1].id, "forensic-batch");
}

#[test]
fn burst_sheds_batch_first_and_keeps_interactive_deadlines() {
    let r = run_preset(None);
    let batch_shed = shed_count(&r, "forensic-batch");
    let interactive_shed = shed_count(&r, "amber-interactive");
    // The burst rides the batch camera's busy window, so the overload
    // machinery (ladder admission shedding + cheapest-victim eviction)
    // must drop batch work...
    assert!(batch_shed > 0, "the seeded burst must force batch shedding");
    // ...while the interactive class is shed last: any interactive loss
    // stays an order of magnitude below the batch loss.
    assert!(
        interactive_shed * 10 <= batch_shed,
        "interactive shed {interactive_shed} vs batch shed {batch_shed}: batch must shed first"
    );
    // The protected class still gets answers, and its tail latency stays
    // bounded — the queue caps turn unbounded waiting into shedding.
    let mut lat: Vec<f64> = r
        .query_verdicts
        .iter()
        .filter(|v| v.query == "amber-interactive" && v.site != "shed")
        .map(|v| v.latency)
        .collect();
    assert!(lat.len() > 20, "interactive query too quiet: {} answers", lat.len());
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = lat[((lat.len() - 1) as f64 * 0.99) as usize];
    assert!(p99 < 8.0, "interactive p99 {p99:.2}s blew the deadline under burst");
}

#[test]
fn overload_run_accounts_for_every_task() {
    let r = run_preset(None);
    assert!(r.faults.shed > 0, "tight caps under burst must shed");
    // Zero-lost invariant: every emitted task is either answered or
    // *explicitly* shed — nothing falls through the cracks silently.
    assert_eq!(r.faults.lost, 0, "shedding must be explicit, never silent loss");
    assert_eq!(
        r.latency.len() as u64 + r.faults.shed,
        r.tasks,
        "answered + shed must equal emitted"
    );
}

#[test]
fn same_seed_overload_reruns_are_byte_identical() {
    let (ra, rb) = (Registry::new(), Registry::new());
    let a = run_preset(Some(ra.clone()));
    let b = run_preset(Some(rb.clone()));
    assert_eq!(a.faults, b.faults, "shed/trip accounting must be seed-deterministic");
    assert_eq!(ra.export_prometheus(), rb.export_prometheus());
    assert_eq!(ra.export_jsonl(), rb.export_jsonl());
    for id in ["amber-interactive", "forensic-batch"] {
        assert_eq!(
            verdicts_jsonl(&a.query_verdicts, id),
            verdicts_jsonl(&b.query_verdicts, id),
            "{id}: same seed must export byte-identical verdict JSONL"
        );
    }
}

#[test]
fn overload_machinery_reports_in_obs() {
    let reg = Registry::new();
    let _ = run_preset(Some(reg.clone()));
    let prom = reg.export_prometheus();
    assert!(prom.contains("surveiledge_overload_shed_total"), "shed counter missing");
    assert!(prom.contains("surveiledge_overload_pressure"), "pressure gauge missing");
    assert!(prom.contains("surveiledge_overload_ladder_level"), "ladder gauge missing");
    assert!(prom.contains("surveiledge_overload_max_queue_depth"), "depth gauge missing");
    let events = reg.export_jsonl();
    assert!(events.contains("\"shed\""), "shed spans missing from the event log");
}

#[test]
fn disabling_the_block_makes_the_subsystem_inert() {
    let qf = preset();
    let mut cfg = qf.cfg;
    cfg.overload = OverloadConfig::default(); // as if the block were absent
    let qs = QuerySet::new(qf.queries).expect("valid specs");
    let reg = Registry::new();
    let r = Harness::builder(cfg)
        .mode(synth())
        .queries(qs)
        .observe(reg.clone())
        .build()
        .run(Scheme::SurveilEdge)
        .expect("run");
    // No caps, no burst, no ladder: every task is answered and not one
    // overload metric leaks into the export.
    assert_eq!(r.faults.shed, 0);
    assert_eq!(r.latency.len() as u64, r.tasks);
    assert!(
        !reg.export_prometheus().contains("surveiledge_overload"),
        "disabled overload control must leave exports untouched"
    );
}
