//! TCP transport integration: bridge restart + client reconnect, recv
//! timeouts, and malformed frames. These exercise the failure paths the
//! fault-injection work leans on — a client must always get a clean
//! signal (None / Err / EOF), never a hang.

use std::io::{Read, Write};
use std::time::Duration;

use surveiledge::bus::tcp::{encode_frame, read_frame, TcpBridge, TcpClient, KIND_PUB};
use surveiledge::bus::{Broker, Message, QoS};

/// Re-bind a freshly stopped port, retrying briefly while the old
/// listener winds down.
fn retry_serve(broker: Broker, port: u16) -> TcpBridge {
    let mut last = None;
    for _ in 0..50 {
        match TcpBridge::serve(broker.clone(), port) {
            Ok(b) => return b,
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    panic!("could not re-bind bridge: {last:?}");
}

#[test]
fn bridge_restart_same_port_allows_reconnect() {
    let broker = Broker::new();
    let (rx, _) = broker.subscribe("restart/#", 64);
    let bridge = TcpBridge::serve(broker.clone(), 0).unwrap();
    let port = bridge.addr.port();
    {
        let mut c = TcpClient::connect(bridge.addr).unwrap();
        c.publish("restart/a", b"before").unwrap();
        let m = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(m.payload.as_slice(), b"before");
    } // client hangs up first, keeping TIME_WAIT off the server port
    std::thread::sleep(Duration::from_millis(200));
    drop(bridge);

    let bridge2 = retry_serve(broker, port);
    assert_eq!(bridge2.addr.port(), port);
    let mut c2 = TcpClient::connect(bridge2.addr).unwrap();
    c2.publish("restart/b", b"after").unwrap();
    let m = rx.recv_timeout(Duration::from_secs(2)).unwrap();
    assert_eq!(m.topic, "restart/b");
    assert_eq!(m.payload.as_slice(), b"after");
}

#[test]
fn recv_timeout_returns_none_not_error() {
    let broker = Broker::new();
    let bridge = TcpBridge::serve(broker, 0).unwrap();
    let mut c = TcpClient::connect(bridge.addr).unwrap();
    c.subscribe("quiet/#").unwrap();
    let t0 = std::time::Instant::now();
    let got = c.recv(Duration::from_millis(200)).unwrap();
    let dt = t0.elapsed();
    assert!(got.is_none(), "nothing was published, recv must time out");
    assert!(dt >= Duration::from_millis(150), "returned too early: {dt:?}");
    assert!(dt < Duration::from_secs(5), "timeout failed to fire: {dt:?}");
}

#[test]
fn corrupt_frame_disconnects_client_without_poisoning_broker() {
    let broker = Broker::new();
    let (rx, _) = broker.subscribe("ok/#", 16);
    let bridge = TcpBridge::serve(broker.clone(), 0).unwrap();
    {
        // A raw socket sends a header with an oversized topic length.
        let mut bad = std::net::TcpStream::connect(bridge.addr).unwrap();
        let mut junk = vec![KIND_PUB];
        junk.extend_from_slice(&60000u16.to_le_bytes());
        junk.extend_from_slice(&0u32.to_le_bytes());
        bad.write_all(&junk).unwrap();
        // The bridge must hang up rather than wedge: wait for EOF/reset.
        bad.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 1];
        match bad.read(&mut buf) {
            Ok(0) => {} // clean disconnect
            Ok(_) => panic!("unexpected data from bridge"),
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {}
            Err(e) => panic!("bridge wedged instead of hanging up: {e}"),
        }
    }
    // A well-formed client still works on the same bridge afterwards.
    let mut good = TcpClient::connect(bridge.addr).unwrap();
    good.publish("ok/x", b"fine").unwrap();
    let m = rx.recv_timeout(Duration::from_secs(2)).unwrap();
    assert_eq!(m.payload.as_slice(), b"fine");
}

#[test]
fn truncated_frame_is_an_error_not_a_hang() {
    // The header promises 4 topic bytes + 2 payload bytes, but the
    // stream ends mid-topic: that is a hard error, not a clean EOF.
    let mut frame = encode_frame(KIND_PUB, "abcd", &[1, 2]);
    frame.truncate(8);
    let mut cursor = std::io::Cursor::new(frame);
    assert!(read_frame(&mut cursor).is_err());
}

#[test]
fn reconnected_subscriber_recovers_state_from_retained() {
    // After a client loses its connection, a fresh subscribe replays the
    // broker's retained state — the recovery path edge nodes use to
    // resync thresholds after a network blip.
    let broker = Broker::new();
    let bridge = TcpBridge::serve(broker.clone(), 0).unwrap();
    broker.publish(Message::retained("state/alpha", vec![7]), QoS::AtMostOnce);
    {
        let mut first = TcpClient::connect(bridge.addr).unwrap();
        first.subscribe("state/#").unwrap();
        let (_, payload) = first.recv(Duration::from_secs(2)).unwrap().expect("retained replay");
        assert_eq!(payload, vec![7]);
    } // connection lost
    let mut again = TcpClient::connect(bridge.addr).unwrap();
    again.subscribe("state/#").unwrap();
    let (topic, payload) = again.recv(Duration::from_secs(2)).unwrap().expect("retained replay");
    assert_eq!(topic, "state/alpha");
    assert_eq!(payload, vec![7]);
}
