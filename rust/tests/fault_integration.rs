//! Fault-injection integration: the seeded chaos scenario from
//! `configs/chaos.toml` (edge 1 crashes at t=10s, 5% uplink drops) must
//! complete with zero lost tasks, and same-seed reruns must reproduce the
//! recovery metrics exactly. Runs entirely in simulated time.

use surveiledge::config::{Config, Scheme};
use surveiledge::faults::{BurstWindow, CrashWindow, FaultPlan, LinkFaults};
use surveiledge::harness::{run_all_schemes, ComputeMode, Harness, RunSpec, SchemeResult};
use surveiledge::obs::Registry;
use surveiledge::overload::{BreakerConfig, OverloadConfig};

fn synth() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn chaos_cfg() -> Config {
    let path = format!("{}/configs/chaos.toml", env!("CARGO_MANIFEST_DIR"));
    Config::from_file(std::path::Path::new(&path)).expect("chaos preset")
}

fn run(cfg: &Config, scheme: Scheme) -> SchemeResult {
    Harness::builder(cfg.clone()).mode(synth()).build().run(scheme).expect("run")
}

#[test]
fn chaos_toml_parses_fault_plan() {
    let cfg = chaos_cfg();
    assert_eq!(cfg.faults.seed, 42);
    assert!((cfg.faults.link.drop_p - 0.05).abs() < 1e-12);
    assert_eq!(
        cfg.faults.crashes,
        vec![CrashWindow { node: 1, from: 10.0, until: 60.0 }]
    );
    assert!(!cfg.faults.is_empty());
}

#[test]
fn seeded_chaos_completes_with_zero_lost_tasks() {
    let cfg = chaos_cfg();
    let r = run(&cfg, Scheme::SurveilEdge);
    assert!(r.tasks > 50, "chaos scenario too quiet: {} tasks", r.tasks);
    // The acceptance bar: every emitted task is answered despite the
    // crash window and the 5% drop rate.
    assert_eq!(r.faults.lost, 0, "lost tasks under chaos");
    assert_eq!(r.latency.len() as u64, r.tasks);
    // The plan is not a no-op: recovery machinery actually fired.
    assert!(
        r.faults.retried + r.faults.rerouted + r.faults.degraded > 0,
        "fault plan produced no recovery activity"
    );
}

#[test]
fn same_seed_reruns_reproduce_recovery_metrics() {
    let cfg = chaos_cfg();
    let a = run(&cfg, Scheme::SurveilEdge);
    let b = run(&cfg, Scheme::SurveilEdge);
    assert_eq!(a.tasks, b.tasks);
    assert_eq!(a.faults, b.faults, "recovery metrics must be seed-reproducible");
    assert!((a.row.avg_latency - b.row.avg_latency).abs() < 1e-12);
    assert!((a.row.bandwidth_mb - b.row.bandwidth_mb).abs() < 1e-12);
}

#[test]
fn different_fault_seed_is_still_safe() {
    let mut cfg = chaos_cfg();
    cfg.faults.seed = 20260807;
    let r = run(&cfg, Scheme::SurveilEdge);
    // A different drop pattern, but no task may fall through the cracks.
    assert_eq!(r.faults.lost, 0);
    assert_eq!(r.latency.len() as u64, r.tasks);
}

#[test]
fn cloud_only_retries_through_heavy_drops() {
    // Cloud-only has no edge fallback: under a 35% drop rate it must keep
    // retrying (bounded backoff) until every upload lands. Widen the
    // uplink so retransmissions cannot saturate the link.
    let mut cfg = Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() };
    cfg.uplink_mbps *= 4.0;
    cfg.faults = FaultPlan {
        seed: 9,
        link: LinkFaults { drop_p: 0.35, ..LinkFaults::default() },
        ..FaultPlan::none()
    };
    let r = run(&cfg, Scheme::CloudOnly);
    assert!(r.faults.retried > 0, "a 35% drop rate must force retries");
    assert_eq!(r.faults.lost, 0, "cloud-only retry loop must deliver everything");
    assert_eq!(r.latency.len() as u64, r.tasks);
    // Retransmissions cost bandwidth: more bytes than tasks alone need.
    assert!(r.row.bandwidth_mb > 0.0);
}

#[test]
fn edge_crash_reroute_reports_detection_lag() {
    // Reroute only begins once the crashed edge's heartbeat goes stale,
    // so time_to_reroute (when a sweep happened) reflects that lag.
    let cfg = chaos_cfg();
    let r = run(&cfg, Scheme::SurveilEdge);
    if r.faults.rerouted > 0 {
        assert!(
            r.faults.time_to_reroute > 0.0 && r.faults.time_to_reroute < 10.0,
            "implausible time-to-reroute {}",
            r.faults.time_to_reroute
        );
    }
    // Link drops alone guarantee some recovery traffic either way.
    assert!(r.faults.retried + r.faults.rerouted > 0);
}

#[test]
fn edge_only_survives_crash_via_recovery_drain() {
    // No allocator: tasks at the crashed edge freeze until the node
    // recovers at t=60, then drain inside the horizon — delayed, not lost.
    let cfg = chaos_cfg();
    let r = run(&cfg, Scheme::EdgeOnly);
    assert_eq!(r.faults.lost, 0);
    assert_eq!(r.latency.len() as u64, r.tasks);
    // The stall shows up as a latency spike on edge-1 frames.
    let edge1_max = r
        .per_frame
        .iter()
        .filter(|(_, _, e)| *e == 1)
        .map(|(_, l, _)| *l)
        .fold(0.0f64, f64::max);
    assert!(edge1_max > 20.0, "expected a crash stall, max edge-1 latency {edge1_max:.1}s");
}

#[test]
fn combined_crash_and_burst_sheds_explicitly_and_loses_nothing() {
    // The hardest seeded scenario: edge 1 crashes at t=10s while a x3
    // detection burst lands on everyone, with tight bounded queues. The
    // zero-lost invariant must still hold — overload control converts
    // overflow into *explicit* sheds, never silent loss.
    let mut cfg = chaos_cfg();
    cfg.overload = OverloadConfig {
        enabled: true,
        node_queue_cap: 4,
        uplink_queue_cap: 3,
        bursts: vec![BurstWindow { from: 20.0, until: 50.0, factor: 3 }],
        ..OverloadConfig::default()
    };
    let r = run(&cfg, Scheme::SurveilEdge);
    assert!(r.faults.shed > 0, "x3 burst into cap-4 queues during a crash must shed");
    assert_eq!(r.faults.lost, 0, "crash + burst must not lose tasks silently");
    assert_eq!(
        r.latency.len() as u64 + r.faults.shed,
        r.tasks,
        "answered + shed must equal emitted under crash + burst"
    );
    // Both layers fired: fault recovery *and* overload control.
    assert!(r.faults.retried + r.faults.rerouted + r.faults.degraded > 0);
}

#[test]
fn retry_budget_caps_the_retry_storm() {
    // Regression for the unbounded-retry amplification: under a heavy
    // drop window, every timed-out upload used to re-enter the uplink
    // immediately, so retransmissions multiplied queue depth. The
    // per-node retry budget bounds how many retries may be in flight;
    // excess work is shed explicitly instead of snowballing.
    let base = || {
        let mut cfg = Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() };
        cfg.faults = FaultPlan {
            seed: 9,
            link: LinkFaults { drop_p: 0.35, ..LinkFaults::default() },
            ..FaultPlan::none()
        };
        cfg.overload = OverloadConfig {
            enabled: true,
            node_queue_cap: 0,  // unbounded: isolate the retry budget
            uplink_queue_cap: 0,
            // A breaker that never trips, for the same reason.
            breaker: BreakerConfig { trip_after: 100_000, ..BreakerConfig::default() },
            ..OverloadConfig::default()
        };
        cfg
    };
    let run_with_budget = |budget: u32| {
        let mut cfg = base();
        cfg.overload.retry_budget = budget;
        let reg = Registry::new();
        let r = Harness::builder(cfg)
            .mode(synth())
            .observe(reg.clone())
            .build()
            .run(Scheme::CloudOnly)
            .expect("run");
        let depth = reg
            .gauge("surveiledge_overload_max_queue_depth", &[("scheme", "cloud-only")])
            .unwrap_or(0.0);
        (r, depth)
    };
    let (unbounded, depth_unbounded) = run_with_budget(0);
    let (capped, depth_capped) = run_with_budget(1);
    assert!(unbounded.faults.retried > 0, "a 35% drop rate must force retries");
    assert!(
        capped.faults.retried < unbounded.faults.retried,
        "budget 1 must strictly cut retries: {} vs {}",
        capped.faults.retried,
        unbounded.faults.retried
    );
    assert!(
        depth_capped <= depth_unbounded,
        "capping retries must not deepen queues: {depth_capped} vs {depth_unbounded}"
    );
    // Bounded does not mean lossy: what the budget refuses is shed.
    assert_eq!(capped.faults.lost, 0);
    assert_eq!(capped.latency.len() as u64 + capped.faults.shed, capped.tasks);
}

#[test]
fn parallel_chaos_run_matches_per_scheme_sequential_runs() {
    // The threaded `run_all_schemes` must not perturb fault handling:
    // each scheme's recovery metrics under the seeded chaos plan are
    // identical to a standalone sequential run of that scheme.
    let cfg = chaos_cfg();
    let results = run_all_schemes(&RunSpec::new(cfg.clone())).expect("parallel chaos run");
    assert_eq!(results.len(), Scheme::all().len());
    for (scheme, par) in Scheme::all().into_iter().zip(&results) {
        let seq = run(&cfg, scheme);
        assert_eq!(par.row.scheme, seq.row.scheme, "spec order must be preserved");
        assert_eq!(par.tasks, seq.tasks, "{scheme:?} task count diverged");
        assert_eq!(par.faults, seq.faults, "{scheme:?} recovery metrics diverged");
        assert!((par.row.avg_latency - seq.row.avg_latency).abs() < 1e-12);
        assert!((par.row.bandwidth_mb - seq.row.bandwidth_mb).abs() < 1e-12);
        assert!((par.row.accuracy - seq.row.accuracy).abs() < 1e-12);
    }
}
