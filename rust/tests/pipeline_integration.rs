//! Full-pipeline integration: inference service + offline stage + online
//! fine-tune + the DES harness in PJRT mode. Skipped when `artifacts/`
//! has not been built; requires the `pjrt` feature (the default build
//! exercises the same pipeline through `runtime::reference` instead).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use surveiledge::config::{Config, Scheme};
use surveiledge::coordinator::{offline_stage, online_fine_tune, OfflineConfig};
use surveiledge::harness::{ComputeMode, Harness, PjrtCtx};
use surveiledge::runtime::service::InferenceService;
use surveiledge::types::ClassId;
use surveiledge::video::standard_deployment;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn service_spawns_and_serves_all_request_kinds() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let svc = InferenceService::spawn(dir, vec![1, 2]).expect("service");
    let h = svc.handle.clone();

    // Edge + cloud inference on a synthetic crop.
    let crop = vec![0.5f32; 32 * 32 * 3];
    let edge_probs = h.edge_infer(1, crop.clone()).unwrap();
    assert_eq!(edge_probs.len(), 2);
    assert!((edge_probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    let cloud_probs = h.cloud_infer(crop.clone()).unwrap();
    assert_eq!(cloud_probs.len(), 8);

    // Unknown edge is an error, not a panic.
    assert!(h.edge_infer(99, crop.clone()).is_err());

    // Frame-diff through the HLO artifact.
    let n = 96 * 128 * 3;
    let prev = vec![0.2f32; n];
    let mut cur = vec![0.2f32; n];
    let mut nxt = vec![0.2f32; n];
    for i in 0..600 {
        cur[10_000 + i] = 0.9;
        nxt[20_000 + i] = 0.9;
    }
    let mask = h.framediff(prev, cur, nxt).unwrap();
    assert_eq!(mask.len(), 96 * 128);

    // Fine-tune on a renderer corpus, then deploy; the deployed model must
    // behave differently from the pretrained one on some crop.
    let (pixels, labels) = surveiledge::harness::finetune_corpus(ClassId::Moped, 96, 5);
    let before = h.edge_infer(1, pixels[..32 * 32 * 3].to_vec()).unwrap();
    let ft = h.fine_tune(pixels.clone(), labels, 12, 0.005, false).unwrap();
    assert_eq!(ft.losses.len(), 12);
    assert!(ft.losses.iter().all(|l| l.is_finite()));
    h.deploy_edge(1, ft.params.clone()).unwrap();
    let after = h.edge_infer(1, pixels[..32 * 32 * 3].to_vec()).unwrap();
    assert!(
        (before[1] - after[1]).abs() > 1e-6,
        "deploying fine-tuned weights changed nothing: {before:?} vs {after:?}"
    );
    // Edge 2 still runs the pretrained weights.
    let other = h.edge_infer(2, pixels[..32 * 32 * 3].to_vec()).unwrap();
    assert!((other[1] - before[1]).abs() < 1e-5, "edge 2 weights must be untouched");

    let stats = h.stats().unwrap();
    assert!(stats.edge_infer.calls >= 4);
    assert!(stats.cloud_infer.calls >= 1);
    assert!(stats.train.calls >= 12);
}

#[test]
fn offline_stage_profiles_clusters_and_datasets() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let svc = InferenceService::spawn(dir, vec![1]).expect("service");
    // 6 cameras alternating Road/Square scenes.
    let mut cams = standard_deployment(6, 96, 128, 33);
    let cfg = OfflineConfig { duration: 60.0, k: 2, ..OfflineConfig::default() };
    let stage = offline_stage(&mut cams, &svc.handle, &cfg).expect("offline stage");

    assert_eq!(stage.profiles.len(), 6);
    for p in &stage.profiles {
        let s: f64 = p.proportions.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
    assert_eq!(stage.clustering.centres.len(), 2);
    let total_crops: usize = stage.datasets.iter().map(|d| d.crops.len()).sum();
    assert!(total_crops > 20, "offline stage produced only {total_crops} labeled crops");

    // Online stage: fine-tune for the cluster containing camera 0.
    let cluster = stage.cluster_of_camera(surveiledge::types::CameraId(0)).unwrap();
    if stage.datasets[cluster].crops.len() >= 48 {
        let ft = online_fine_tune(
            &svc.handle,
            &stage.datasets[cluster],
            ClassId::Moped,
            &[1],
            10,
            9,
        )
        .expect("online fine-tune");
        assert_eq!(ft.losses.len(), 10);
    }
}

#[test]
fn harness_pjrt_mode_single_edge() {
    let Some(_dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let cfg = Config {
        duration: 30.0,
        artifacts: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ..Config::single_edge()
    };
    let ctx = PjrtCtx::prepare(&cfg, 10).expect("pjrt ctx");
    let mut h = Harness::builder(cfg).mode(ComputeMode::Pjrt(Box::new(ctx))).build();
    let r = h.run(Scheme::SurveilEdge).expect("run");
    assert!(r.tasks > 5, "PJRT harness produced only {} tasks", r.tasks);
    assert_eq!(r.latency.len() as u64, r.tasks);
    assert!(r.row.accuracy > 0.3, "PJRT accuracy {}", r.row.accuracy);
    assert!(r.row.avg_latency > 0.0);
}

#[test]
fn harness_pjrt_cloud_only_is_oracle() {
    let Some(_dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let cfg = Config {
        duration: 20.0,
        artifacts: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ..Config::single_edge()
    };
    let ctx = PjrtCtx::prepare(&cfg, 0).expect("pjrt ctx");
    let mut h = Harness::builder(cfg).mode(ComputeMode::Pjrt(Box::new(ctx))).build();
    let r = h.run(Scheme::CloudOnly).expect("run");
    // Accuracy vs the oracle is 1.0 by construction in cloud-only.
    assert!((r.row.accuracy - 1.0).abs() < 1e-9);
    assert!(r.row.bandwidth_mb > 0.0);
}
