//! Harness integration over synthetic compute: scheme orderings that the
//! paper's tables rely on, run on the three prototype settings. These run
//! without artifacts (synthetic confidences) so they always execute.

use surveiledge::config::{Config, Scheme};
use surveiledge::harness::{ComputeMode, Harness, SchemeResult};

fn synth() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn run(cfg: &Config, scheme: Scheme) -> SchemeResult {
    let mut h = Harness::builder(cfg.clone()).mode(synth()).build();
    h.run(scheme).expect("run")
}

#[test]
fn table2_shape_single_edge() {
    // Full calibrated frame size: the 48x64 shortcut merges sprites into
    // blobs and under-loads the single edge, washing out the Table II
    // latency ordering.
    let cfg = Config { duration: 240.0, ..Config::single_edge() };
    let se = run(&cfg, Scheme::SurveilEdge);
    let fixed = run(&cfg, Scheme::SurveilEdgeFixed);
    let eo = run(&cfg, Scheme::EdgeOnly);
    let co = run(&cfg, Scheme::CloudOnly);

    // Paper Table II orderings:
    assert!((co.row.accuracy - 1.0).abs() < 1e-9, "cloud-only is ground truth");
    assert!(se.row.accuracy > eo.row.accuracy, "SE acc {} vs edge-only {}", se.row.accuracy, eo.row.accuracy);
    assert!(se.row.avg_latency < co.row.avg_latency, "SE lat {} vs cloud-only {}", se.row.avg_latency, co.row.avg_latency);
    assert!(se.row.avg_latency < eo.row.avg_latency, "SE lat {} vs edge-only {}", se.row.avg_latency, eo.row.avg_latency);
    assert_eq!(eo.row.bandwidth_mb, 0.0);
    assert!(co.row.bandwidth_mb >= se.row.bandwidth_mb, "bandwidth: CO {} >= SE {}", co.row.bandwidth_mb, se.row.bandwidth_mb);
    assert!(fixed.row.bandwidth_mb < se.row.bandwidth_mb, "fixed uploads less than SE (paper Table II)");
}

#[test]
fn table3_shape_homogeneous() {
    let cfg = Config { duration: 240.0, frame_h: 48, frame_w: 64, ..Config::homogeneous() };
    let se = run(&cfg, Scheme::SurveilEdge);
    let eo = run(&cfg, Scheme::EdgeOnly);
    let fixed = run(&cfg, Scheme::SurveilEdgeFixed);
    // Multi-edge: the allocator exploits staggered busy windows, so the
    // speedup over edge-only/fixed grows (paper: 15.8x / 16.2x).
    assert!(se.row.avg_latency < eo.row.avg_latency);
    assert!(se.row.avg_latency < fixed.row.avg_latency);
    assert!(se.row.accuracy > eo.row.accuracy);
}

#[test]
fn table4_shape_heterogeneous() {
    let cfg = Config { duration: 240.0, frame_h: 48, frame_w: 64, ..Config::heterogeneous() };
    let se = run(&cfg, Scheme::SurveilEdge);
    let eo = run(&cfg, Scheme::EdgeOnly);
    // The weak (0.25x) edge collapses in edge-only; SE drains it.
    assert!(se.row.avg_latency < eo.row.avg_latency);
    // Variance story (Fig. 8): SE's p99 is far below edge-only's.
    assert!(se.latency.percentile(0.99) < eo.latency.percentile(0.99));
}

#[test]
fn hetero_slowest_edge_dominates_edge_only_tail() {
    let cfg = Config { duration: 240.0, frame_h: 48, frame_w: 64, ..Config::heterogeneous() };
    let eo = run(&cfg, Scheme::EdgeOnly);
    // Group per-frame latencies by home edge: edge 1 (speed 0.25) should
    // have a worse mean than edge 3 (speed 1.0) under edge-only.
    let mean_for = |edge: u32| {
        let xs: Vec<f64> = eo.per_frame.iter().filter(|(_, _, e)| *e == edge).map(|(_, l, _)| *l).collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let slow = mean_for(1);
    let fast = mean_for(3);
    assert!(slow > fast, "slow edge mean {slow} should exceed fast edge mean {fast}");
}

#[test]
fn surveiledge_tail_beats_fixed_tail() {
    // Fig. 6(b)/7: the adaptive scheme prevents the ever-growing queueing
    // tail the fixed scheme suffers.
    let cfg = Config { duration: 240.0, frame_h: 48, frame_w: 64, ..Config::homogeneous() };
    let se = run(&cfg, Scheme::SurveilEdge);
    let fixed = run(&cfg, Scheme::SurveilEdgeFixed);
    assert!(se.latency.percentile(0.95) < fixed.latency.percentile(0.95));
}

#[test]
fn pdf_data_for_figures_is_well_formed() {
    let cfg = Config { duration: 120.0, frame_h: 48, frame_w: 64, ..Config::single_edge() };
    let se = run(&cfg, Scheme::SurveilEdge);
    let (centres, dens) = se.latency.pdf(30);
    assert_eq!(centres.len(), 30);
    let width = centres[1] - centres[0];
    let integral: f64 = dens.iter().map(|d| d * width).sum();
    assert!((integral - 1.0).abs() < 1e-6);
    // Per-frame series exists for the line plots.
    assert_eq!(se.per_frame.len() as u64, se.tasks);
}

#[test]
fn edge_outage_rerouting() {
    // Extension experiment (failure injection): edge 1 goes dark for
    // t in [60, 120). SurveilEdge reroutes its tasks; edge-only stalls them
    // until recovery. Compare the latency impact on edge-1 frames.
    use surveiledge::harness::EdgeOutage;
    let cfg = Config { duration: 240.0, ..Config::homogeneous() };
    let outage = EdgeOutage { edge: 1, from: 60.0, until: 120.0 };

    let se = Harness::builder(cfg.clone())
        .mode(synth())
        .outage(outage)
        .build()
        .run(Scheme::SurveilEdge)
        .unwrap();
    let eo = Harness::builder(cfg.clone())
        .mode(synth())
        .outage(outage)
        .build()
        .run(Scheme::EdgeOnly)
        .unwrap();

    let edge1_mean = |r: &SchemeResult| {
        let xs: Vec<f64> = r.per_frame.iter().filter(|(_, _, e)| *e == 1).map(|(_, l, _)| *l).collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let se_m = edge1_mean(&se);
    let eo_m = edge1_mean(&eo);
    assert!(
        se_m < eo_m,
        "allocator should absorb the outage: SE edge1 mean {se_m:.2}s vs edge-only {eo_m:.2}s"
    );
    // Edge-only must show a severe stall (tasks held >= tens of seconds).
    let eo_max = eo
        .per_frame
        .iter()
        .filter(|(_, _, e)| *e == 1)
        .map(|(_, l, _)| *l)
        .fold(0.0f64, f64::max);
    assert!(eo_max > 30.0, "expected a stall spike under edge-only, max {eo_max:.1}s");
    // All tasks still answered eventually under both schemes.
    assert_eq!(se.latency.len() as u64, se.tasks);
}

#[test]
fn shipped_config_presets_load_and_run() {
    for preset in ["single_edge", "homogeneous", "heterogeneous", "bicycle_query", "chaos"] {
        let path = format!("{}/configs/{preset}.toml", env!("CARGO_MANIFEST_DIR"));
        let mut cfg = Config::from_file(std::path::Path::new(&path))
            .unwrap_or_else(|e| panic!("{preset}: {e}"));
        cfg.duration = 30.0; // shrink for the test
        let r = Harness::builder(cfg).mode(synth()).build().run(Scheme::SurveilEdge).unwrap();
        assert!(r.tasks > 0, "{preset} produced no tasks");
    }
}
