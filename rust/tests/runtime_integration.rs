//! Integration tests: the AOT artifact bundle executed through the PJRT
//! runtime. These are the tests that prove the three layers compose:
//! Python-trained weights + Pallas-lowered HLO + Rust execution reproduce
//! the Python-side golden outputs bit-for-bit (within f32 tolerance).
//!
//! Skipped (cleanly) when `artifacts/` has not been built. The whole file
//! requires the `pjrt` feature (the runtime bridge is compiled out of the
//! default build).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use surveiledge::runtime::{read_blob, Engine, MomentumSgd};
use surveiledge::types::Image;
use surveiledge::video::sprite::{render_sprite, SpriteParams};
use surveiledge::types::ClassId;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Each test owns an Engine: `PjRtClient` is `Rc`-based (thread-bound),
/// and multiple CPU clients coexist happily in one process.
fn engine() -> Option<Engine> {
    artifact_dir().map(|d| Engine::new(&d).expect("engine"))
}

macro_rules! require_artifacts {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_is_consistent() {
    let eng = require_artifacts!();
    let m = &eng.manifest;
    assert_eq!(m.img, 32);
    assert_eq!(m.classes.len(), 8);
    assert!(m.edge_params.len() >= 10);
    assert!(m.cloud_params.len() >= 10);
    assert!(m.edge_head_group <= m.edge_params.len());
    for key in ["edge_infer_b1", "edge_infer_b8", "cloud_infer_b1", "cloud_infer_b8", "edge_train", "framediff"] {
        assert!(m.artifacts.contains_key(key), "missing artifact {key}");
        assert!(m.artifact_path(key).unwrap().exists());
    }
}

#[test]
fn edge_model_reproduces_golden_probs() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let batch = read_blob(&dir.join("golden_batch.bin")).unwrap();
    let want = read_blob(&dir.join("golden_edge_probs.bin")).unwrap();
    let params = eng.edge_pretrained().unwrap();
    let model = eng.edge_model(8, &params).unwrap();
    let got = model.infer(&batch).unwrap();
    assert_eq!(got.len(), 8);
    for (i, row) in got.iter().enumerate() {
        assert_eq!(row.len(), 2);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
        for (j, &p) in row.iter().enumerate() {
            let w = want[i * 2 + j];
            assert!((p - w).abs() < 1e-3, "edge prob[{i}][{j}] {p} vs golden {w}");
        }
    }
}

#[test]
fn cloud_model_reproduces_golden_probs() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let batch = read_blob(&dir.join("golden_batch.bin")).unwrap();
    let want = read_blob(&dir.join("golden_cloud_probs.bin")).unwrap();
    let params = eng.cloud_trained().unwrap();
    let model = eng.cloud_model(8, &params).unwrap();
    let got = model.infer(&batch).unwrap();
    for (i, row) in got.iter().enumerate() {
        for (j, &p) in row.iter().enumerate() {
            let w = want[i * 8 + j];
            assert!((p - w).abs() < 1e-3, "cloud prob[{i}][{j}] {p} vs golden {w}");
        }
    }
}

#[test]
fn cloud_classifies_golden_batch_correctly() {
    // The golden batch is one sprite per class in order; the trained cloud
    // CNN should get (nearly) all of them right — it is the ground truth.
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let batch = read_blob(&dir.join("golden_batch.bin")).unwrap();
    let params = eng.cloud_trained().unwrap();
    let model = eng.cloud_model(8, &params).unwrap();
    let got = model.infer(&batch).unwrap();
    let correct = got
        .iter()
        .enumerate()
        .filter(|(i, row)| {
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            argmax == *i
        })
        .count();
    assert!(correct >= 6, "cloud got only {correct}/8 of its own classes");
}

#[test]
fn batch1_matches_batch8() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let batch = read_blob(&dir.join("golden_batch.bin")).unwrap();
    let params = eng.edge_pretrained().unwrap();
    let m8 = eng.edge_model(8, &params).unwrap();
    let m1 = eng.edge_model(1, &params).unwrap();
    let full = m8.infer(&batch).unwrap();
    let px = 32 * 32 * 3;
    for i in 0..8 {
        let one = m1.infer(&batch[i * px..(i + 1) * px]).unwrap();
        for j in 0..2 {
            assert!(
                (one[0][j] - full[i][j]).abs() < 1e-4,
                "b1 vs b8 mismatch at {i},{j}: {} vs {}",
                one[0][j],
                full[i][j]
            );
        }
    }
}

#[test]
fn rust_rendered_sprite_is_classified_by_cloud() {
    // Full cross-language loop: sprite rendered in RUST, classified by the
    // Python-trained cloud CNN through PJRT. This is the test that pins
    // the two renderer implementations to the same distribution.
    let eng = require_artifacts!();
    let params = eng.cloud_trained().unwrap();
    let model = eng.cloud_model(1, &params).unwrap();
    let mut correct = 0;
    let classes = [ClassId::Car, ClassId::Bus, ClassId::Person, ClassId::Moped];
    for (k, cls) in classes.iter().enumerate() {
        let sprite = render_sprite(&SpriteParams {
            cls: *cls,
            size: 24,
            base: [0.75, 0.25, 0.2],
            accent: [0.2, 0.35, 0.8],
            bg: [0.45, 0.47, 0.44],
            rot: 0.05,
            jx: 0.02,
            jy: -0.02,
            noise: 0.04,
            seed: 7000 + k as u32,
        });
        let crop = sprite.resize(32, 32);
        let probs = model.infer(&crop.data).unwrap();
        let argmax = probs[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == cls.index() {
            correct += 1;
        }
    }
    assert!(correct >= 3, "cloud CNN recognised only {correct}/4 rust-rendered sprites");
}

#[test]
fn golden_resize_matches_python() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let input = read_blob(&dir.join("golden_resize_in.bin")).unwrap();
    let want = read_blob(&dir.join("golden_resize_out.bin")).unwrap();
    let img = Image { h: 24, w: 24, data: input };
    let out = img.resize(32, 32);
    assert_eq!(out.data.len(), want.len());
    let max_diff = out
        .data
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "rust resize deviates from python by {max_diff}");
}

#[test]
fn golden_sprites_match_python_renderer() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let blob = read_blob(&dir.join("golden_sprites.bin")).unwrap();
    let per = 24 * 24 * 3;
    assert_eq!(blob.len(), 8 * per);
    for cls in 0..8usize {
        let p = SpriteParams {
            cls: ClassId::from_index(cls).unwrap(),
            size: 24,
            base: [0.8, 0.2, 0.2],
            accent: [0.2, 0.3, 0.8],
            bg: [0.45, 0.47, 0.44],
            rot: 0.15,
            jx: 0.05,
            jy: -0.04,
            noise: 0.06,
            seed: 1000 + cls as u32,
        };
        let img = render_sprite(&p);
        let want = &blob[cls * per..(cls + 1) * per];
        // Hard-mask rasterisation: tiny trig differences can flip boundary
        // pixels, so compare by mismatch fraction, not exact equality.
        let mismatches = img
            .data
            .iter()
            .zip(want)
            .filter(|(a, b)| (*a - *b).abs() > 1e-3)
            .count();
        let frac = mismatches as f64 / per as f64;
        assert!(frac < 0.01, "class {cls}: {:.3}% pixels differ from python", frac * 100.0);
    }
}

#[test]
fn finetune_step_decreases_loss_and_updates_head_only() {
    let eng = require_artifacts!();
    let trainer = eng.trainer().unwrap();
    let mut params = eng.edge_pretrained().unwrap();
    let before = params.clone();
    let n = params.len();
    let mask = MomentumSgd::head_only_mask(n, eng.manifest.edge_head_group);
    let mut opt = MomentumSgd::new(&eng.manifest.edge_params, 0.005, mask.clone());

    // Build a fine-tune batch from rust-rendered sprites: query = moped.
    let b = trainer.batch;
    let mut pixels = Vec::with_capacity(b * 32 * 32 * 3);
    let mut labels = Vec::with_capacity(b);
    for i in 0..b {
        let is_pos = i % 2 == 0;
        let cls = if is_pos { ClassId::Moped } else { ClassId::Car };
        let sprite = render_sprite(&SpriteParams {
            cls,
            size: 20 + (i % 8),
            base: [0.3 + 0.05 * (i % 5) as f32, 0.5, 0.4],
            accent: [0.6, 0.3, 0.2 + 0.05 * (i % 4) as f32],
            bg: [0.45, 0.47, 0.44],
            rot: 0.02 * i as f32 - 0.3,
            jx: 0.0,
            jy: 0.0,
            noise: 0.05,
            seed: 9000 + i as u32,
        });
        pixels.extend_from_slice(&sprite.resize(32, 32).data);
        labels.push(is_pos as i32);
    }

    let mut losses = Vec::new();
    for _ in 0..20 {
        let out = trainer.grad_step(&params, &pixels, &labels).unwrap();
        assert!(out.loss.is_finite());
        losses.push(out.loss);
        opt.step(&mut params, &out.grads);
    }
    let first3: f32 = losses[..3].iter().sum::<f32>() / 3.0;
    let last3: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
    assert!(last3 < first3, "fine-tune loss did not decrease: {losses:?}");
    // Masked (backbone) params unchanged; head params moved.
    for i in 0..n {
        let changed = params[i] != before[i];
        assert_eq!(changed, mask[i], "param {i} mask violation (changed={changed})");
    }
}

#[test]
fn framediff_hlo_matches_native() {
    let eng = require_artifacts!();
    let fd = eng.framediff().unwrap();
    let (h, w) = (fd.h, fd.w);
    // A moving block triplet.
    let mut prev = Image::filled(h, w, [0.5, 0.5, 0.5]);
    let mut cur = prev.clone();
    let mut nxt = prev.clone();
    for y in 20..40 {
        for x in 10..30 {
            prev.set(y, x, [1.0, 0.9, 0.8]);
        }
        for x in 40..60 {
            cur.set(y, x, [1.0, 0.9, 0.8]);
        }
        for x in 70..90 {
            nxt.set(y, x, [1.0, 0.9, 0.8]);
        }
    }
    let got = fd.mask(&prev.data, &cur.data, &nxt.data).unwrap();
    let want = surveiledge::detect::framediff::framediff_native(&prev, &cur, &nxt, 0.1);
    assert_eq!(got.len(), want.len());
    let diff = got.iter().zip(&want).filter(|(a, b)| a != b).count();
    assert_eq!(diff, 0, "HLO vs native framediff: {diff} differing pixels");
    assert!(got.iter().any(|&m| m == 1), "mask must detect the moving block");
}

#[test]
fn deployed_weight_swap_changes_output() {
    let eng = require_artifacts!();
    let dir = &eng.manifest.dir;
    let batch1: Vec<f32> = read_blob(&dir.join("golden_batch.bin")).unwrap()[..32 * 32 * 3].to_vec();
    let params = eng.edge_pretrained().unwrap();
    let mut model = eng.edge_model(1, &params).unwrap();
    let before = model.infer(&batch1).unwrap()[0].clone();
    // Perturb the head weights and redeploy (what fine-tune deployment does).
    let mut newp = params.clone();
    let n = newp.len();
    for v in newp[n - 2].iter_mut() {
        *v += 0.5;
    }
    model.set_params(&newp).unwrap();
    let after = model.infer(&batch1).unwrap()[0].clone();
    assert!(
        (before[1] - after[1]).abs() > 1e-6,
        "weight swap had no effect: {before:?} vs {after:?}"
    );
}

#[test]
fn microbatcher_pads_and_splits_correctly() {
    let eng = require_artifacts!();
    use std::time::Duration;
    use surveiledge::runtime::batcher::MicroBatcher;

    let params = eng.edge_pretrained().unwrap();
    // Reference answers from the b1 model.
    let m1 = eng.edge_model(1, &params).unwrap();
    let dir = &eng.manifest.dir;
    let batch = read_blob(&dir.join("golden_batch.bin")).unwrap();
    let px = 32 * 32 * 3;

    let m8 = eng.edge_model(8, &params).unwrap();
    let (mut batcher, handle) = MicroBatcher::new(m8, 64, Duration::from_millis(5));

    // Send 5 requests (partial batch -> padding) from another thread.
    let senders: Vec<std::thread::JoinHandle<Vec<f32>>> = (0..5)
        .map(|i| {
            let h = handle.clone();
            let crop = batch[i * px..(i + 1) * px].to_vec();
            std::thread::spawn(move || h.infer(crop).unwrap())
        })
        .collect();
    // Pump windows until all replies are in.
    let mut pumps = 0;
    while pumps < 50 {
        batcher.pump(Duration::from_millis(10));
        pumps += 1;
        if batcher.stats().requests >= 5 {
            break;
        }
    }
    for (i, s) in senders.into_iter().enumerate() {
        let got = s.join().unwrap();
        let want = m1.infer(&batch[i * px..(i + 1) * px]).unwrap()[0].clone();
        for j in 0..2 {
            assert!(
                (got[j] - want[j]).abs() < 1e-4,
                "batched row {i} col {j}: {} vs b1 {}",
                got[j],
                want[j]
            );
        }
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, 5);
    assert!(stats.batches >= 1);
}

#[test]
fn microbatcher_isolates_bad_requests() {
    let eng = require_artifacts!();
    use std::time::Duration;
    use surveiledge::runtime::batcher::MicroBatcher;

    let params = eng.edge_pretrained().unwrap();
    let m8 = eng.edge_model(8, &params).unwrap();
    let (mut batcher, handle) = MicroBatcher::new(m8, 8, Duration::from_millis(2));

    let bad = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer(vec![0.0; 10])) // wrong size
    };
    let good = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer(vec![0.5; 32 * 32 * 3]))
    };
    let mut pumps = 0;
    while pumps < 50 && batcher.stats().requests < 2 {
        batcher.pump(Duration::from_millis(10));
        pumps += 1;
    }
    assert!(bad.join().unwrap().is_err(), "bad-size request must fail alone");
    let probs = good.join().unwrap().expect("good request must survive the batch");
    assert_eq!(probs.len(), 2);
}
