//! Regenerates the paper's Tables II, III and IV: the four query schemes
//! on the single / homogeneous / heterogeneous settings.
//!
//!     cargo bench --bench bench_tables
//!
//! Env knobs: BENCH_DURATION (stream seconds, default 240),
//! BENCH_PJRT=1 to route classifications through the AOT artifacts,
//! BENCH_SEQUENTIAL=1 to run the schemes one at a time instead of on
//! scoped threads (the A/B used to record the wall-clock delta in
//! EXPERIMENTS.md §Perf).

use surveiledge::config::{Config, Scheme};
use surveiledge::harness::{run_all_schemes, standard_mode, Harness, RunSpec, SchemeResult};
use surveiledge::metrics::render_table;

fn duration() -> f64 {
    std::env::var("BENCH_DURATION").ok().and_then(|v| v.parse().ok()).unwrap_or(240.0)
}

fn use_pjrt() -> bool {
    std::env::var("BENCH_PJRT").map(|v| v == "1").unwrap_or(false)
}

fn sequential() -> bool {
    std::env::var("BENCH_SEQUENTIAL").map(|v| v == "1").unwrap_or(false)
}

/// The pre-refactor behavior: one scheme at a time on the calling
/// thread. Kept behind BENCH_SEQUENTIAL=1 so the parallel speedup is
/// measurable with the same binary.
fn run_sequential(cfg: &Config, pjrt: bool) -> anyhow::Result<Vec<SchemeResult>> {
    Scheme::all()
        .into_iter()
        .map(|scheme| {
            let mode = standard_mode(cfg, pjrt)?;
            Harness::builder(cfg.clone()).mode(mode).build().run(scheme)
        })
        .collect()
}

fn run_setting(title: &str, mut cfg: Config) -> anyhow::Result<()> {
    cfg.duration = duration();
    let pjrt = use_pjrt();
    let t0 = std::time::Instant::now();
    let results = if sequential() {
        run_sequential(&cfg, pjrt)?
    } else {
        run_all_schemes(&RunSpec::new(cfg).pjrt(pjrt))?
    };
    let rows: Vec<_> = results.iter().map(|r| r.row.clone()).collect();
    println!("{}", render_table(title, &rows));
    for r in &results {
        println!(
            "  {:20} tasks={} uploads={} p50={:.2}s p99={:.2}s std={:.2}s",
            r.row.scheme,
            r.tasks,
            r.uploads,
            r.latency.percentile(0.5),
            r.latency.percentile(0.99),
            r.latency.std()
        );
    }
    // Paper headline ratios for this setting.
    let find = |name: &str| rows.iter().find(|r| r.scheme == name).unwrap();
    let se = find("SurveilEdge");
    let eo = find("edge-only");
    let co = find("cloud-only");
    println!(
        "  headline: vs cloud-only {:.1}x faster, {:.1}x less bandwidth; vs edge-only {:.1}x faster, +{:.1}% accuracy",
        co.avg_latency / se.avg_latency.max(1e-9),
        co.bandwidth_mb / se.bandwidth_mb.max(1e-9),
        eo.avg_latency / se.avg_latency.max(1e-9),
        (se.accuracy - eo.accuracy) * 100.0
    );
    println!(
        "  ({} compute, {} schemes, {:.1}s wall)\n",
        if pjrt { "PJRT" } else { "synthetic" },
        if sequential() { "sequential" } else { "parallel" },
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("# SurveilEdge — Tables II-IV reproduction\n");
    run_setting("Table II — single edge and cloud", Config::single_edge())?;
    run_setting("Table III — homogeneous edges and cloud", Config::homogeneous())?;
    run_setting("Table IV — heterogeneous edges and cloud", Config::heterogeneous())?;
    Ok(())
}
