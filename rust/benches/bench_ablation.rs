//! Ablations of the design choices DESIGN.md §8 calls out:
//!
//!   1. threshold adaptation on/off (SurveilEdge vs fixed) across load —
//!      beyond the tables, a sweep over busy intensity;
//!   2. latency estimator: eq. 17 vs plain EWMA vs lognormal-only, scored
//!      by prediction error on a heavy-tailed latency stream;
//!   3. allocator policy: eq. 7 (argmin Q·t) vs random vs round-robin on
//!      the heterogeneous setting;
//!   4. γ₁ sensitivity of the controller.
//!
//!     cargo bench --bench bench_ablation
//! Env: BENCH_DURATION (default 180).

use surveiledge::config::{Config, Scheme};
use surveiledge::estimator::{adaptive_mean_update, Lognormal3};
use surveiledge::harness::{ComputeMode, Harness};
use surveiledge::testkit::Rng;

fn duration() -> f64 {
    std::env::var("BENCH_DURATION").ok().and_then(|v| v.parse().ok()).unwrap_or(180.0)
}

fn synth() -> ComputeMode {
    ComputeMode::synthetic_default()
}

/// Ablation 1: adaptive vs fixed thresholds under varying uplink capacity
/// (the resource whose congestion the controller reacts to).
fn ablate_controller() -> anyhow::Result<()> {
    println!("## Ablation 1 — adaptive vs fixed thresholds vs uplink capacity\n");
    println!("| uplink (Mbps) | SE F2 | SE lat | fixed F2 | fixed lat |");
    println!("|---------------|-------|--------|----------|-----------|");
    for uplink in [2.0, 4.0, 6.0, 12.0] {
        let cfg = Config { duration: duration(), uplink_mbps: uplink, ..Config::single_edge() };
        let se = Harness::builder(cfg.clone()).mode(synth()).build().run(Scheme::SurveilEdge)?;
        let fx = Harness::builder(cfg).mode(synth()).build().run(Scheme::SurveilEdgeFixed)?;
        println!(
            "| {uplink:.0} | {:.3} | {:6.2}s | {:.3} | {:6.2}s |",
            se.row.accuracy, se.row.avg_latency, fx.row.accuracy, fx.row.avg_latency
        );
    }
    println!("\nexpected shape: fixed degrades sharply when the uplink tightens; adaptive holds latency by narrowing the band.\n");
    Ok(())
}

/// Ablation 2: estimator variants on a lognormal latency stream with
/// occasional 20x outliers; scored by mean absolute prediction error
/// against the stream's true (clean) mean.
fn ablate_estimators() {
    println!("## Ablation 2 — latency estimator variants\n");
    let mut rng = Rng::new(17);
    let (mu, sigma, gamma) = (-1.2f64, 0.4, 0.1);
    let true_mean = gamma + (mu + sigma * sigma / 2.0).exp();

    let mut eq17 = 0.4f64;
    let mut ewma = 0.4f64;
    let mut logn = Lognormal3::new(256, 32);
    let (mut err17, mut errew, mut errln) = (0.0f64, 0.0f64, 0.0f64);
    let mut ln_n = 0usize;
    let n = 20_000;
    for i in 0..n {
        let mut x = rng.lognormal3(mu, sigma, gamma);
        if rng.bool(0.01) {
            x *= 20.0; // stray outlier (paper's motivation for eq. 17)
        }
        eq17 = adaptive_mean_update(eq17, x);
        ewma = 0.9 * ewma + 0.1 * x;
        logn.observe(x);
        if i > 500 {
            err17 += (eq17 - true_mean).abs();
            errew += (ewma - true_mean).abs();
            if let Some(p) = logn.predict() {
                errln += (p - true_mean).abs();
                ln_n += 1;
            }
        }
    }
    let m = (n - 501) as f64;
    println!("| estimator | mean abs error |");
    println!("|-----------|----------------|");
    println!("| eq. 17 self-weighted | {:.4} |", err17 / m);
    println!("| EWMA (0.1) | {:.4} |", errew / m);
    println!("| lognormal-3 MLE | {:.4} |", errln / ln_n.max(1) as f64);
    println!("\nexpected shape: eq. 17 beats EWMA under outliers; the lognormal fit is steadiest but refreshes slowly.\n");
}

/// Ablation 3: γ₁ sensitivity (controller step size).
fn ablate_gamma1() -> anyhow::Result<()> {
    println!("## Ablation 3 — controller step size γ1\n");
    println!("| γ1 | F2 | avg latency | bandwidth (MB) |");
    println!("|----|----|-------------|----------------|");
    for gamma1 in [0.02, 0.05, 0.1, 0.3, 0.8] {
        let cfg = Config { duration: duration(), gamma1, ..Config::single_edge() };
        let r = Harness::builder(cfg).mode(synth()).build().run(Scheme::SurveilEdge)?;
        println!(
            "| {gamma1} | {:.3} | {:6.2}s | {:7.1} |",
            r.row.accuracy, r.row.avg_latency, r.row.bandwidth_mb
        );
    }
    println!();
    Ok(())
}

/// Ablation 4: negative-sampling rule (proportional vs uniform) — measured
/// on the selection distribution itself (the CNN-level effect is in
/// python/tests/test_train.py).
fn ablate_negative_sampling() {
    use surveiledge::coordinator::{select_training_set, ClusterDataset, LabeledCrop};
    use surveiledge::types::{CameraId, ClassId, Image};
    println!("## Ablation 4 — proportional vs uniform negative sampling\n");
    let mut ds = ClusterDataset {
        crops: Vec::new(),
        profile: [0.55, 0.02, 0.02, 0.2, 0.05, 0.06, 0.05, 0.05],
    };
    for cls in [ClassId::Car, ClassId::Bus, ClassId::Moped, ClassId::Person] {
        for i in 0..60 {
            ds.crops.push(LabeledCrop {
                camera: CameraId(0),
                label: cls,
                crop: Image::filled(32, 32, [i as f32 / 60.0, 0.5, 0.5]),
            });
        }
    }
    let (_, labels) = select_training_set(&ds, ClassId::Moped, 400, 0.5, 3);
    let pos = labels.iter().filter(|&&l| l == 1).count();
    println!("proportional (paper §IV-B): {} samples, {:.1}% positives;", labels.len(), 100.0 * pos as f64 / labels.len() as f64);
    println!("negatives follow the cluster profile (car-heavy here), so the CQ-CNN sees the");
    println!("confusable common classes most often — python/tests/test_train.py shows the");
    println!("accuracy effect on the trained model.\n");
}

/// Ablation 5 (extension): failure injection — edge 1 dark for a quarter
/// of the run; how much does the allocator absorb?
fn ablate_outage() -> anyhow::Result<()> {
    use surveiledge::harness::EdgeOutage;
    println!("## Ablation 5 — edge outage (failure injection, extension)\n");
    let cfg = Config { duration: duration(), ..Config::homogeneous() };
    let outage = EdgeOutage { edge: 1, from: duration() / 4.0, until: duration() / 2.0 };
    println!("| scheme | healthy lat | with-outage lat | outage penalty |");
    println!("|--------|-------------|-----------------|----------------|");
    for scheme in [Scheme::SurveilEdge, Scheme::SurveilEdgeFixed, Scheme::EdgeOnly] {
        let healthy = Harness::builder(cfg.clone()).mode(synth()).build().run(scheme)?;
        let faulted =
            Harness::builder(cfg.clone()).mode(synth()).outage(outage).build().run(scheme)?;
        println!(
            "| {} | {:6.2}s | {:6.2}s | {:+6.2}s |",
            scheme.name(),
            healthy.row.avg_latency,
            faulted.row.avg_latency,
            faulted.row.avg_latency - healthy.row.avg_latency
        );
    }
    println!("\nexpected shape: the eq. 7 allocator absorbs most of the outage; queue-bound schemes stall.\n");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("# SurveilEdge — design ablations\n");
    ablate_controller()?;
    ablate_estimators();
    ablate_gamma1()?;
    ablate_negative_sampling();
    ablate_outage()?;
    Ok(())
}
