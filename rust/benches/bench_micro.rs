//! Micro-benchmarks of the hot-path components (benchkit harness):
//! allocator decision, threshold update, estimators, detection stages,
//! bus publish, paramdb put, renderer — plus the PJRT inference calls
//! when the artifact bundle is present. Drives the §Perf pass.
//!
//!     cargo bench --bench bench_micro
//!
//! Env: BENCH_TARGET_SECS per benchmark (default 1.0).

use surveiledge::benchkit::Bench;
use surveiledge::bus::{Broker, Message, QoS};
use surveiledge::detect::framediff::framediff_native;
use surveiledge::detect::{detect, DetectConfig};
use surveiledge::estimator::{adaptive_mean_update, fit_lognormal3, LatencyEstimator};
use surveiledge::paramdb::{ParamDb, Value};
use surveiledge::sched::{allocate, NodeLoad, ThresholdConfig, ThresholdController};
use surveiledge::testkit::Rng;
use surveiledge::types::{ClassId, Image, NodeId};
use surveiledge::video::sprite::{render_sprite, SpriteParams};
use surveiledge::video::{standard_deployment, Camera};

fn rand_image(rng: &mut Rng, h: usize, w: usize) -> Image {
    let mut img = Image::new(h, w);
    for v in img.data.iter_mut() {
        *v = rng.f32();
    }
    img
}

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::new();
    let mut rng = Rng::new(42);

    Bench::header("L3 hot-path micro-benchmarks");

    // --- scheduler -------------------------------------------------------
    let loads: Vec<NodeLoad> = (0..4)
        .map(|i| NodeLoad {
            node: NodeId(i),
            queue: (i * 3) as usize,
            t_infer: 0.1 + i as f64 * 0.05,
            penalty: if i == 0 { 0.4 } else { 0.0 },
        })
        .collect();
    bench.run("sched::allocate (4 nodes)", || allocate(&loads));

    let mut ctl = ThresholdController::new(0.8, ThresholdConfig::default());
    let mut q = 0usize;
    bench.run("sched::threshold_update", || {
        q = (q + 1) % 20;
        ctl.update(q, 0.25);
        ctl.alpha
    });

    // --- estimators --------------------------------------------------------
    bench.run("estimator::eq17_update", || adaptive_mean_update(0.31, 0.42));

    let mut est = LatencyEstimator::new(0.3);
    let mut x = 0.25f64;
    bench.run("estimator::combined_observe", || {
        x = 0.2 + (x * 7.13) % 0.4;
        est.observe(x);
        est.estimate()
    });

    let samples: Vec<f64> = {
        let mut r = Rng::new(9);
        (0..256).map(|_| r.lognormal3(-1.0, 0.4, 0.1)).collect()
    };
    bench.run("estimator::lognormal3_fit (n=256)", || fit_lognormal3(&samples));

    // --- detection ----------------------------------------------------------
    let prev = rand_image(&mut rng, 96, 128);
    let cur = rand_image(&mut rng, 96, 128);
    let nxt = rand_image(&mut rng, 96, 128);
    bench.run("detect::framediff_native 96x128", || framediff_native(&prev, &cur, &nxt, 0.1));

    let mut cam = standard_deployment(1, 96, 128, 5).remove(0);
    let f0 = cam.frame_at(30.0).image;
    let f1 = cam.frame_at(31.0).image;
    let f2 = cam.frame_at(32.0).image;
    let dcfg = DetectConfig::default();
    bench.run("detect::full_pipeline 96x128", || detect(&f0, &f1, &f2, &dcfg));

    // --- substrate ------------------------------------------------------------
    let broker = Broker::new();
    let (_rx, _id) = broker.subscribe("bench/#", 1 << 14);
    let payload = vec![0u8; 256];
    bench.run("bus::publish qos0 (1 sub, 256B)", || {
        broker.publish(Message::new("bench/x", payload.clone()), QoS::AtMostOnce)
    });

    let db = ParamDb::new();
    bench.run("paramdb::put", || db.put("t/1", Value::F64(0.25)));
    bench.run("paramdb::get", || db.get_f64("t/1"));

    // --- renderer / video -------------------------------------------------------
    let sprite_params = SpriteParams {
        cls: ClassId::Moped,
        size: 24,
        base: [0.8, 0.2, 0.2],
        accent: [0.2, 0.3, 0.8],
        bg: [0.45, 0.47, 0.44],
        rot: 0.1,
        jx: 0.0,
        jy: 0.0,
        noise: 0.08,
        seed: 7,
    };
    bench.run("sprite::render 24x24", || render_sprite(&sprite_params));

    let mut cam2 = Camera::new(
        standard_deployment(1, 96, 128, 6).remove(0).spec,
        96,
        128,
    );
    let mut t = 0.0;
    bench.run("video::frame_at 96x128", || {
        t += 1.0;
        cam2.frame_at(t).seq
    });

    let crop = rand_image(&mut rng, 24, 24);
    bench.run("image::resize 24->32", || crop.resize(32, 32));

    // --- reference classifier (default-build CNN stand-in) -----------------------
    let clf = surveiledge::runtime::reference::ReferenceClassifier::new(32);
    let ref_crop = vec![0.5f32; 32 * 32 * 3];
    bench.run("reference::cloud_probs 32x32", || clf.cloud_probs(&ref_crop).unwrap().len());

    // --- PJRT (artifact-dependent, `--features pjrt`) -----------------------------
    #[cfg(feature = "pjrt")]
    pjrt_benches(&mut bench)?;
    #[cfg(not(feature = "pjrt"))]
    println!("\n(built without the `pjrt` feature; skipping PJRT micro-benchmarks)");

    println!("\n{} benchmarks completed", bench.results().len());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(bench: &mut Bench) -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(artifacts/ not built; skipping PJRT micro-benchmarks)");
        return Ok(());
    }
    Bench::header("PJRT inference (AOT artifacts)");
    let engine = surveiledge::runtime::Engine::new(std::path::Path::new("artifacts"))?;
    let edge1 = engine.edge_model(1, &engine.edge_pretrained()?)?;
    let edge8 = engine.edge_model(8, &engine.edge_pretrained()?)?;
    let cloud1 = engine.cloud_model(1, &engine.cloud_trained()?)?;
    let fd = engine.framediff()?;
    let crop1 = vec![0.5f32; 32 * 32 * 3];
    let crop8 = vec![0.5f32; 8 * 32 * 32 * 3];
    bench.run("pjrt::edge_infer b1", || edge1.infer(&crop1).unwrap().len());
    bench.run("pjrt::edge_infer b8", || edge8.infer(&crop8).unwrap().len());
    bench.run("pjrt::cloud_infer b1", || cloud1.infer(&crop1).unwrap().len());
    let fh = engine.manifest.frame_h;
    let fw = engine.manifest.frame_w;
    let fr = vec![0.4f32; fh * fw * 3];
    bench.run("pjrt::framediff_hlo", || fd.mask(&fr, &fr, &fr).unwrap().len());
    // Ablation companion: native vs HLO dense stage at the same size.
    let p2 = Image { h: fh, w: fw, data: fr.clone() };
    bench.run("detect::framediff_native (same size)", || {
        framediff_native(&p2, &p2, &p2, 0.1)
    });
    Ok(())
}
