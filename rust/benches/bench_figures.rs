//! Regenerates the data behind Figs. 6, 7 and 8: per-scheme latency PDFs
//! (sub-figure (a)) and per-frame latency series (sub-figures (b)-(d),
//! grouped per edge for the multi-edge settings). Output is CSV blocks,
//! ready to plot.
//!
//!     cargo bench --bench bench_figures
//!
//! Env knobs: BENCH_DURATION (default 240), FIG_CSV_DIR (write CSVs there
//! in addition to stdout summaries).

use surveiledge::config::Config;
use surveiledge::harness::{run_all_schemes, RunSpec};
use surveiledge::metrics::render_csv;

fn duration() -> f64 {
    std::env::var("BENCH_DURATION").ok().and_then(|v| v.parse().ok()).unwrap_or(240.0)
}

fn dump(name: &str, csv: &str) {
    if let Ok(dir) = std::env::var("FIG_CSV_DIR") {
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(format!("{dir}/{name}.csv"), csv);
        println!("  wrote {dir}/{name}.csv");
    }
}

fn figure(fig: &str, cfg: Config, edges: &[u32]) -> anyhow::Result<()> {
    println!("## Fig. {fig} — latency PDFs + per-frame series\n");
    // All four schemes run concurrently; results come back in spec order.
    for r in run_all_schemes(&RunSpec::new(cfg))? {
        // (a): PDF of per-frame latency.
        let (centres, dens) = r.latency.pdf(40);
        let csv = render_csv(&["latency_s", "density"], &[&centres, &dens]);
        println!(
            "Fig.{fig}(a) {:20} mean={:7.2}s std={:6.2}s p99={:7.2}s  (PDF: {} bins)",
            r.row.scheme,
            r.latency.mean(),
            r.latency.std(),
            r.latency.percentile(0.99),
            centres.len()
        );
        dump(&format!("fig{fig}_pdf_{}", r.row.scheme.replace(&['(', ')'][..], "")), &csv);

        // (b)-(d): per-frame series, per home edge.
        for &edge in edges {
            let times: Vec<f64> = r
                .per_frame
                .iter()
                .filter(|(_, _, e)| *e == edge)
                .map(|(t, _, _)| *t)
                .collect();
            let lats: Vec<f64> = r
                .per_frame
                .iter()
                .filter(|(_, _, e)| *e == edge)
                .map(|(_, l, _)| *l)
                .collect();
            if lats.is_empty() {
                continue;
            }
            let mean = lats.iter().sum::<f64>() / lats.len() as f64;
            let max = lats.iter().cloned().fold(0.0, f64::max);
            println!(
                "         edge{edge}: {:5} frames, mean {:7.2}s, max {:7.2}s",
                lats.len(),
                mean,
                max
            );
            let csv = render_csv(&["t", "latency_s"], &[&times, &lats]);
            dump(
                &format!("fig{fig}_series_{}_edge{edge}", r.row.scheme.replace(&['(', ')'][..], "")),
                &csv,
            );
        }
    }
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("# SurveilEdge — Figs. 6-8 reproduction\n");
    let d = duration();
    figure("6", Config { duration: d, ..Config::single_edge() }, &[1])?;
    figure("7", Config { duration: d, ..Config::homogeneous() }, &[1, 2, 3])?;
    figure("8", Config { duration: d, ..Config::heterogeneous() }, &[1, 2, 3])?;
    Ok(())
}
