//! Regenerates Fig. 5: the three CNN training schemes compared —
//! accuracy reached vs training work, normalised as in the paper.
//!
//!   * No Fine-tune  — pretrained generic weights (0 training);
//!   * SurveilEdge   — head-group fine-tuning from pretrained weights;
//!   * All Fine-tune — full from-scratch training.
//!
//! Runs the real edge_train HLO through PJRT (needs `make artifacts`);
//! exits early (with a notice) when the bundle is absent so `cargo bench`
//! stays green in a fresh checkout.
//!
//! Env: FIG5_SE_STEPS (default 40), FIG5_ALL_STEPS (default 320).

use std::time::Instant;

use surveiledge::harness::finetune_corpus;
use surveiledge::runtime::service::InferenceService;
use surveiledge::types::ClassId;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    println!("# SurveilEdge — Fig. 5 reproduction (training schemes)\n");
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts/ not built — run `make artifacts` first. Skipping.");
        return Ok(());
    }
    let svc = InferenceService::spawn("artifacts".into(), vec![1])?;
    let h = svc.handle.clone();
    let query = ClassId::Moped;
    let (train_px, train_lb) = finetune_corpus(query, 256, 11);
    let (test_px, test_lb) = finetune_corpus(query, 160, 99);
    let px = 32 * 32 * 3;

    let eval = |h: &surveiledge::runtime::service::ServiceHandle| -> anyhow::Result<f64> {
        let mut correct = 0usize;
        for (i, &label) in test_lb.iter().enumerate() {
            let probs = h.edge_infer(1, test_px[i * px..(i + 1) * px].to_vec())?;
            correct += ((probs[1] >= 0.5) as i32 == label) as usize;
        }
        Ok(correct as f64 / test_lb.len() as f64)
    };

    // No Fine-tune.
    let acc_none = eval(&h)?;

    // SurveilEdge fine-tune.
    let se_steps = env_usize("FIG5_SE_STEPS", 40);
    let t = Instant::now();
    let ft = h.fine_tune(train_px.clone(), train_lb.clone(), se_steps, 0.005, false)?;
    let se_secs = t.elapsed().as_secs_f64();
    h.deploy_edge(1, ft.params)?;
    let acc_se = eval(&h)?;

    // All Fine-tune (from scratch).
    let all_steps = env_usize("FIG5_ALL_STEPS", 320);
    let t = Instant::now();
    let ft = h.fine_tune(train_px, train_lb, all_steps, 0.01, true)?;
    let all_secs = t.elapsed().as_secs_f64();
    h.deploy_edge(1, ft.params)?;
    let acc_all = eval(&h)?;

    // Normalised presentation (the paper normalises both axes).
    let max_acc = acc_se.max(acc_all).max(acc_none).max(1e-9);
    let max_time = all_secs.max(se_secs).max(1e-9);
    println!("| scheme | steps | train time | rel. time | accuracy | rel. accuracy |");
    println!("|--------|-------|-----------|-----------|----------|----------------|");
    println!(
        "| No Fine-tune | 0 | 0.0s | 0.00 | {:.1}% | {:.2} |",
        acc_none * 100.0,
        acc_none / max_acc
    );
    println!(
        "| SurveilEdge | {se_steps} | {se_secs:.1}s | {:.2} | {:.1}% | {:.2} |",
        se_secs / max_time,
        acc_se * 100.0,
        acc_se / max_acc
    );
    println!(
        "| All Fine-tune | {all_steps} | {all_secs:.1}s | {:.2} | {:.1}% | {:.2} |",
        all_secs / max_time,
        acc_all * 100.0,
        acc_all / max_acc
    );
    println!(
        "\ntraining-time reduction (All/SE): {:.1}x  — paper reports ~8x at equal accuracy",
        all_secs / se_secs.max(1e-9)
    );
    Ok(())
}
