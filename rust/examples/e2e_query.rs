//! End-to-end driver (the repo's full-stack proof): a *live*, threaded
//! serving run in which every classification is a real PJRT execution of
//! the AOT-compiled CNNs. All layers compose here:
//!
//!   1. offline stage — synthetic cameras stream pixels; the frame-difference
//!      detector finds objects; the cloud CNN labels them; K-Means clusters
//!      the camera profiles; per-cluster datasets are built;
//!   2. online stage — on the query ("moped"), a CQ-specific CNN is
//!      fine-tuned per cluster via the edge_train HLO and deployed;
//!   3. serving — edge threads sample/detect/classify, apply the [β,α]
//!      band, upload doubtful crops over the MQTT-like bus; a cloud thread
//!      re-classifies; verdicts, latency and throughput are reported.
//!
//! Requires `make artifacts`. Run:
//!
//!     cargo run --release --example e2e_query

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use surveiledge::bus::{Broker, QoS};
use surveiledge::config::Scheme;
use surveiledge::coordinator::{offline_stage, online_fine_tune, OfflineConfig};
use surveiledge::detect::{detect, DetectConfig};
use surveiledge::nodes::{
    controller_for, decode_task, CloudWorker, EdgeWorker, NodeState, RunMetrics,
};
use surveiledge::paramdb::ParamDb;
use surveiledge::runtime::service::InferenceService;
use surveiledge::simclock::{Clock, RealClock};
use surveiledge::types::{ClassId, NodeId, Task};
use surveiledge::video::standard_deployment;

const N_EDGES: u32 = 2;
const CAMS_PER_EDGE: usize = 2;
const SERVE_SECS: f64 = 20.0;
const QUERY: ClassId = ClassId::Moped;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("SURVEILEDGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("== SurveilEdge end-to-end (live PJRT serving) ==\n");

    // ---- boot the inference service (owns the PJRT engine) -------------
    let t0 = Instant::now();
    let svc = InferenceService::spawn(artifacts.into(), (1..=N_EDGES).collect())?;
    println!("[boot]    inference service up in {:.2}s", t0.elapsed().as_secs_f64());

    // ---- offline stage ---------------------------------------------------
    let t1 = Instant::now();
    let n_cams = N_EDGES as usize * CAMS_PER_EDGE;
    let mut cams = standard_deployment(n_cams, 96, 128, 42);
    let stage = offline_stage(
        &mut cams,
        &svc.handle,
        &OfflineConfig { duration: 150.0, ..OfflineConfig::default() },
    )?;
    println!(
        "[offline] {} cameras -> {} clusters, datasets: {:?} crops ({:.1}s)",
        n_cams,
        stage.clustering.centres.len(),
        stage.datasets.iter().map(|d| d.crops.len()).collect::<Vec<_>>(),
        t1.elapsed().as_secs_f64()
    );

    // ---- online stage: fine-tune + deploy per cluster ---------------------
    let t2 = Instant::now();
    for (ci, ds) in stage.datasets.iter().enumerate() {
        // Edges whose cameras belong to this cluster get its CQ-CNN.
        let edges: Vec<u32> = (1..=N_EDGES)
            .filter(|e| {
                (0..CAMS_PER_EDGE).any(|k| {
                    let cam = surveiledge::types::CameraId(((e - 1) as usize * CAMS_PER_EDGE + k) as u32);
                    stage.cluster_of_camera(cam) == Some(ci)
                })
            })
            .collect();
        let positives = ds.crops.iter().filter(|c| c.label == QUERY).count();
        if ds.crops.len() < 40 || edges.is_empty() {
            println!(
                "[online]  cluster {ci}: generic weights kept (crops={}, {positives} positive, edges={edges:?})",
                ds.crops.len()
            );
            continue;
        }
        match online_fine_tune(&svc.handle, ds, QUERY, &edges, 25, 7) {
            Ok(ft) => println!(
                "[online]  cluster {ci}: fine-tuned {} steps in {:.1}s (loss {:.3} -> {:.3}, acc {:.2}, {positives} positives) -> edges {edges:?}",
                ft.losses.len(),
                ft.train_secs,
                ft.losses.first().unwrap_or(&0.0),
                ft.losses.last().unwrap_or(&0.0),
                ft.accs.last().unwrap_or(&0.0),
            ),
            Err(e) => println!("[online]  cluster {ci}: generic weights kept ({e})"),
        }
    }
    println!("[online]  total {:.1}s", t2.elapsed().as_secs_f64());

    // ---- live serving ------------------------------------------------------
    let broker = Broker::new();
    let db = ParamDb::new();
    let metrics = Arc::new(RunMetrics::default());
    let clock = Arc::new(RealClock::new());

    // Cloud worker thread: consumes doubtful uploads.
    let (cloud_rx, _) = broker.subscribe("task/cloud", 512);
    let cloud_state = NodeState::new(NodeId::CLOUD, 0.01);
    let cloud = CloudWorker {
        state: cloud_state,
        service: svc.handle.clone(),
        broker: broker.clone(),
        db: db.clone(),
        metrics: metrics.clone(),
        query: QUERY,
    };
    let cloud_clock = clock.clone();
    let cloud_thread = std::thread::spawn(move || {
        let now = move || cloud_clock.now();
        while let Ok(msg) = cloud_rx.recv() {
            if msg.payload.is_empty() {
                break; // shutdown sentinel
            }
            if let Ok(up) = decode_task(&msg.payload) {
                let _ = cloud.classify(up, &now);
            }
        }
    });

    // Edge threads: sample cameras, detect, classify.
    let mut edge_threads = Vec::new();
    let mut task_counter = 0u64;
    let mut cam_sets: Vec<Vec<surveiledge::video::Camera>> = Vec::new();
    // Re-create the cameras for serving (offline pass consumed stream time).
    let mut all = standard_deployment(n_cams, 96, 128, 43);
    for _ in 0..N_EDGES {
        let rest = all.split_off(CAMS_PER_EDGE.min(all.len()));
        cam_sets.push(all);
        all = rest;
    }
    for (ei, mut cams) in cam_sets.into_iter().enumerate() {
        let edge_id = ei as u32 + 1;
        let worker = EdgeWorker {
            state: NodeState::new(NodeId(edge_id), 0.05),
            scheme: Scheme::SurveilEdge,
            controller: Mutex::new(controller_for(Scheme::SurveilEdge, 0.1, 0.25, 0.02)),
            service: svc.handle.clone(),
            broker: broker.clone(),
            db: db.clone(),
            metrics: metrics.clone(),
            query: QUERY,
            slowdown: 1.0,
            queries: None,
            overload: None,
        };
        let clock = clock.clone();
        let base_id = task_counter;
        task_counter += 1_000_000;
        edge_threads.push(std::thread::spawn(move || {
            let now = move || clock.now();
            let dcfg = DetectConfig::default();
            let mut id = base_id;
            let interval = 0.5f64; // sample faster than 1s to pack the run
            let mut t = interval;
            let mut history: Vec<_> = cams.iter_mut().map(|c| (c.frame_at(0.0), None)).collect();
            while now() < SERVE_SECS {
                for (ci, cam) in cams.iter_mut().enumerate() {
                    let frame = cam.frame_at(t);
                    let truth = cam.truth_at(t);
                    let (prev, prev2) = &mut history[ci];
                    if let Some(p2) = prev2.take() {
                        for det in detect(&p2, &prev.image, &frame.image, &dcfg) {
                            let bb = det.bbox.expand(dcfg.margin, frame.image.h, frame.image.w);
                            let crop = prev
                                .image
                                .crop(bb.y0, bb.x0, bb.y1, bb.x1)
                                .resize(dcfg.crop_size, dcfg.crop_size);
                            let truth_cls = truth
                                .iter()
                                .map(|(c, tb)| (*c, det.bbox.iou(tb)))
                                .filter(|(_, iou)| *iou > 0.2)
                                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                                .map(|(c, _)| c);
                            id += 1;
                            let task = Task {
                                id,
                                camera: frame.camera,
                                frame_seq: frame.seq,
                                t_capture: now(),
                                t_detected: now(),
                                bbox: det.bbox,
                                crop,
                                truth: truth_cls,
                            };
                            worker.state.queue.fetch_add(1, Ordering::Relaxed);
                            let _ = worker.classify(task, &now);
                            worker.state.queue.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                    let old_prev = std::mem::replace(prev, frame);
                    *prev2 = Some(old_prev.image);
                }
                t += interval;
            }
        }));
    }

    for th in edge_threads {
        th.join().expect("edge thread");
    }
    // Stop the cloud worker once the upload queue drains.
    std::thread::sleep(std::time::Duration::from_millis(300));
    broker.publish(surveiledge::bus::Message::new("task/cloud", vec![]), QoS::AtLeastOnce);
    cloud_thread.join().expect("cloud thread");

    // ---- report -------------------------------------------------------------
    let lat = metrics.latency.lock().unwrap();
    let oracle = metrics.vs_oracle.lock().unwrap();
    let truth = metrics.vs_truth.lock().unwrap();
    let bw = metrics.bandwidth.lock().unwrap();
    let stats = svc.handle.stats()?;
    let answered = lat.len();
    println!("\n== serving report ({SERVE_SECS:.0}s live) ==");
    println!("  verdicts:            {answered} ({:.1}/s)", answered as f64 / SERVE_SECS);
    println!(
        "  answered at edge:    {}  uploaded to cloud: {}",
        metrics.answered_at_edge.load(Ordering::Relaxed),
        metrics.uploads.load(Ordering::Relaxed)
    );
    println!(
        "  latency: mean {:.1} ms  p50 {:.1} ms  p99 {:.1} ms",
        lat.mean() * 1e3,
        lat.percentile(0.5) * 1e3,
        lat.percentile(0.99) * 1e3
    );
    println!(
        "  F2 vs truth: {:.3} ({} scored)   cloud-verdict agreement: {:.3}",
        truth.f2(),
        truth.total(),
        oracle.accuracy()
    );
    println!("  upload bandwidth:    {:.2} MB", bw.cloud_bytes() as f64 / 1048576.0);
    println!(
        "  service: edge {:.2} ms/call x{}, cloud {:.2} ms/call x{}, framediff {:.2} ms/call x{}",
        stats.edge_infer.mean() * 1e3,
        stats.edge_infer.calls,
        stats.cloud_infer.mean() * 1e3,
        stats.cloud_infer.calls,
        stats.framediff.mean() * 1e3,
        stats.framediff.calls
    );
    anyhow::ensure!(answered > 0, "no verdicts produced");
    println!("\nOK: all three layers composed (python-AOT artifacts -> PJRT -> live pipeline).");
    Ok(())
}
