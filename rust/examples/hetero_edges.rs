//! Heterogeneous-edges scenario (paper §V-D, Table IV + Fig. 8): three
//! edges with 2/4/8-core-equivalent speed factors. Shows per-edge latency
//! series — the weak edge collapses under edge-only, and the allocator
//! drains it under SurveilEdge.
//!
//!     cargo run --release --example hetero_edges [--pjrt]

use surveiledge::config::Config;
use surveiledge::harness::{run_all_schemes, RunSpec};
use surveiledge::metrics::render_table;

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");
    let cfg = Config { duration: 240.0, ..Config::heterogeneous() };
    println!(
        "scenario: 3 heterogeneous edges (speed {:?}), query = {}\n",
        cfg.edges.iter().map(|e| e.speed).collect::<Vec<_>>(),
        cfg.query
    );

    // All four schemes run concurrently on scoped threads.
    let results = run_all_schemes(&RunSpec::new(cfg.clone()).pjrt(pjrt))?;
    let mut rows = Vec::new();
    for r in results {
        // Per-edge latency summary (Fig. 8 (b)-(d) data).
        println!("{}:", r.row.scheme);
        for edge in 1..=3u32 {
            let xs: Vec<f64> = r
                .per_frame
                .iter()
                .filter(|(_, _, e)| *e == edge)
                .map(|(_, l, _)| *l)
                .collect();
            if xs.is_empty() {
                continue;
            }
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let max = xs.iter().cloned().fold(0.0, f64::max);
            println!(
                "  edge{edge} (speed {:.2}): {:4} frames, mean {:7.2}s, max {:7.2}s",
                cfg.edges[(edge - 1) as usize].speed,
                xs.len(),
                mean,
                max
            );
        }
        rows.push(r.row);
    }

    println!("\n{}", render_table("Table IV layout — heterogeneous edges and cloud", &rows));
    println!("paper's shape: SurveilEdge ~10x faster than edge-only/fixed; weak edge dominates their tails.");
    Ok(())
}
