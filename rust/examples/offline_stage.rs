//! Offline stage walkthrough (paper §IV-A, Fig. 3): camera profiling,
//! K-Means clustering, and per-cluster training-dataset assembly, with the
//! crops labeled by the real cloud CNN over PJRT.
//!
//! Requires `make artifacts`. Run:
//!
//!     cargo run --release --example offline_stage

use surveiledge::cluster::silhouette;
use surveiledge::coordinator::{offline_stage, OfflineConfig};
use surveiledge::runtime::service::InferenceService;
use surveiledge::types::CLASS_NAMES;
use surveiledge::video::standard_deployment;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("SURVEILEDGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let svc = InferenceService::spawn(artifacts.into(), vec![1])?;

    // 8 cameras: even = road scenes, odd = square scenes.
    let n = 8;
    let mut cams = standard_deployment(n, 96, 128, 33);
    let stage = offline_stage(
        &mut cams,
        &svc.handle,
        &OfflineConfig { duration: 60.0, k: 2, ..OfflineConfig::default() },
    )?;

    println!("== camera profiles (proportion vectors, Fig. 3) ==\n");
    println!("           {}", CLASS_NAMES.map(|c| format!("{c:>8}")).join(""));
    for p in &stage.profiles {
        let cells: String = p.proportions.iter().map(|x| format!("{x:>8.2}")).collect();
        let kind = if p.camera.0 % 2 == 0 { "road  " } else { "square" };
        println!(
            "cam{:<2} {kind} {cells}   -> cluster {}",
            p.camera.0,
            stage.clustering.assignment[p.camera.0 as usize]
        );
    }

    println!("\n== clustering ==");
    for (i, centre) in stage.clustering.centres.iter().enumerate() {
        let cells: String = centre.iter().map(|x| format!("{x:>8.2}")).collect();
        println!("cluster {i} profile: {cells}");
    }
    println!("silhouette: {:.3}", silhouette(&stage.profiles, &stage.clustering));
    println!("inertia:    {:.4}", stage.clustering.inertia);

    println!("\n== context-specific datasets ==");
    for (i, ds) in stage.datasets.iter().enumerate() {
        let mut counts = [0usize; 8];
        for c in &ds.crops {
            counts[c.label.index()] += 1;
        }
        println!("cluster {i}: {} crops, label mix:", ds.crops.len());
        for (name, cnt) in CLASS_NAMES.iter().zip(counts.iter()) {
            if *cnt > 0 {
                println!("    {name:>8}: {cnt}");
            }
        }
    }
    Ok(())
}
