//! Fig. 5 reproduction: the three training schemes compared.
//!
//!   * No Fine-tune  — pretrained generic weights used as-is (0 steps);
//!   * SurveilEdge   — head-group fine-tuning from pretrained weights;
//!   * All Fine-tune — full training from scratch.
//!
//! For each scheme we report query-classification accuracy on a held-out
//! context corpus vs training steps, plus wall-clock training time. The
//! paper's finding to reproduce: SurveilEdge reaches All-Fine-tune-level
//! accuracy with roughly an order of magnitude less training.
//!
//! Requires `make artifacts`. Run:
//!
//!     cargo run --release --example train_schemes

use std::time::Instant;

use surveiledge::harness::finetune_corpus;
use surveiledge::runtime::service::InferenceService;
use surveiledge::types::ClassId;

const QUERY: ClassId = ClassId::Moped;

fn accuracy(handle: &surveiledge::runtime::service::ServiceHandle, edge: u32,
            pixels: &[f32], labels: &[i32]) -> anyhow::Result<f64> {
    let px = 32 * 32 * 3;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let probs = handle.edge_infer(edge, pixels[i * px..(i + 1) * px].to_vec())?;
        let pred = (probs[1] >= 0.5) as i32;
        correct += (pred == label) as usize;
    }
    Ok(correct as f64 / labels.len() as f64)
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("SURVEILEDGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let svc = InferenceService::spawn(artifacts.into(), vec![1])?;
    let h = svc.handle.clone();

    // Context corpus (train) + held-out corpus (eval).
    let (train_px, train_lb) = finetune_corpus(QUERY, 256, 11);
    let (test_px, test_lb) = finetune_corpus(QUERY, 128, 99);

    println!("== Fig. 5: training schemes (query = {QUERY}) ==\n");
    println!("| scheme | steps | train time | eval accuracy |");
    println!("|--------|-------|------------|---------------|");

    // --- No Fine-tune: the pretrained generic weights, untouched. -------
    let acc0 = accuracy(&h, 1, &test_px, &test_lb)?;
    println!("| No Fine-tune | 0 | 0.0s | {:.1}% |", acc0 * 100.0);

    // --- SurveilEdge: head-group fine-tune, few steps. -------------------
    let mut se_time = 0.0;
    let mut se_best = 0.0f64;
    for steps in [10usize, 25, 50] {
        let t = Instant::now();
        let ft = h.fine_tune(train_px.clone(), train_lb.clone(), steps, 0.005, false)?;
        let secs = t.elapsed().as_secs_f64();
        h.deploy_edge(1, ft.params)?;
        let acc = accuracy(&h, 1, &test_px, &test_lb)?;
        se_best = se_best.max(acc);
        se_time = secs;
        println!("| SurveilEdge | {steps} | {secs:.1}s | {:.1}% |", acc * 100.0);
    }

    // --- All Fine-tune: from-scratch training, many steps. ---------------
    let mut all_time = 0.0;
    let mut all_best = 0.0f64;
    for steps in [50usize, 150, 400] {
        let t = Instant::now();
        let ft = h.fine_tune(train_px.clone(), train_lb.clone(), steps, 0.01, true)?;
        let secs = t.elapsed().as_secs_f64();
        h.deploy_edge(1, ft.params)?;
        let acc = accuracy(&h, 1, &test_px, &test_lb)?;
        all_best = all_best.max(acc);
        all_time = secs;
        println!("| All Fine-tune | {steps} | {secs:.1}s | {:.1}% |", acc * 100.0);
    }

    println!("\nsummary:");
    println!("  No Fine-tune accuracy:     {:.1}%", acc0 * 100.0);
    println!("  SurveilEdge best accuracy: {:.1}%  (last run {se_time:.1}s)", se_best * 100.0);
    println!("  All Fine-tune best:        {:.1}%  (last run {all_time:.1}s)", all_best * 100.0);
    if se_time > 0.0 {
        println!("  training-time ratio (all/SE): {:.1}x", all_time / se_time);
    }
    println!("\npaper's Fig. 5 shape: SurveilEdge ~= All Fine-tune accuracy at ~8x less training; both >> No Fine-tune.");
    Ok(())
}
