//! Quickstart: run a 2-minute single-edge-plus-cloud query for "moped"
//! and print a paper-style result row per scheme.
//!
//!     cargo run --release --example quickstart
//!
//! Uses calibrated synthetic confidences so it runs without the artifact
//! bundle; pass `--pjrt` after `make artifacts` to route every
//! classification through the real AOT-compiled CNNs.

use surveiledge::config::Config;
use surveiledge::harness::{run_all_schemes, RunSpec};
use surveiledge::metrics::render_table;

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");
    let cfg = Config { duration: 120.0, ..Config::single_edge() };

    println!(
        "scenario: 1 edge ({} cameras), 1 cloud, query = {}, interval = {}s, {}s of stream\n",
        cfg.total_cameras(),
        cfg.query,
        cfg.interval,
        cfg.duration
    );

    // One call runs all four schemes on scoped threads; results arrive in
    // spec order, each identical to a standalone sequential run.
    let mut rows = Vec::new();
    for result in run_all_schemes(&RunSpec::new(cfg).pjrt(pjrt))? {
        println!(
            "{:20} {:4} tasks, {:4} uploads, p99 latency {:.2}s",
            result.row.scheme,
            result.tasks,
            result.uploads,
            result.latency.percentile(0.99)
        );
        rows.push(result.row);
    }
    println!("\n{}", render_table("quickstart (Table II layout)", &rows));
    Ok(())
}
