//! Offline API stub of the PJRT surface of the `xla` crate.
//!
//! The `surveiledge` runtime bridge (`rust/src/runtime`) is written against
//! the PJRT CPU-client API of the `xla` crate: load an HLO text module,
//! compile it once, upload weights as device buffers, and execute from the
//! request path. That crate links a vendored XLA C++ build, which is not
//! available in the offline build environment — so this stub provides the
//! same *types and signatures* with runtime-erroring bodies, letting
//! `cargo build --features pjrt` type-check and link the entire gated path
//! with no network access and no C++ toolchain.
//!
//! To actually execute the AOT artifacts, replace the `xla` path dependency
//! in `rust/Cargo.toml` with the real crate; no `surveiledge` source changes
//! are needed. Every constructor here returns [`XlaError`] immediately
//! (`PjRtClient::cpu()` is the entry point), so the stub can never produce
//! wrong numbers — only a clear "rebuild against real XLA" error.

use std::borrow::Borrow;
use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub struct XlaError {
    message: String,
}

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError {
            message: format!(
                "{what}: this build links the offline `xla` API stub; point the `xla` \
                 path dependency in rust/Cargo.toml at the real crate (vendored XLA \
                 C++ runtime) to execute PJRT artifacts"
            ),
        }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for XlaError {}

/// Result alias matching the real crate's fallible API.
pub type Result<T> = std::result::Result<T, XlaError>;

/// A parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (always errors in the stub).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// A computation ready for compilation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module (infallible in the real crate, so also here).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-resident buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to the host as a literal (always errors).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Extract element 0 of a tuple literal (always errors).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(XlaError::unavailable("Literal::to_tuple1"))
    }

    /// Extract all elements of a tuple literal (always errors).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed host vector (always errors).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with buffer arguments; accepts owned or borrowed buffers
    /// (`&[PjRtBuffer]` and `&[&PjRtBuffer]`), like the real crate.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT CPU client (stub). `cpu()` is the only constructor and it
/// errors immediately, so no other stubbed method is reachable at runtime.
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client (always errors in the stub).
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    /// Upload a host slice as a device buffer (always errors).
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    /// Compile a computation (always errors).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_explanatory() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn from_text_file_errors() {
        let Err(_) = HloModuleProto::from_text_file("x.hlo.txt") else {
            panic!("stub from_text_file must fail");
        };
    }
}
