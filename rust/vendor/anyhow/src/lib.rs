//! Offline shim of the `anyhow` error-handling API.
//!
//! The SurveilEdge build must succeed with no network access and no crate
//! registry cache, so this workspace vendors the small subset of `anyhow`
//! the codebase uses instead of fetching the real crate:
//!
//! * [`Error`] — a boxed, type-erased error with `Display`/`Debug`, an
//!   alternate (`{:#}`) chain rendering, and [`Error::downcast_ref`];
//! * [`Result`] — `Result<T, Error>` with a default error type;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, exactly like the real crate. `Context`/backtrace support is
//! intentionally omitted (nothing in this workspace uses it).

use std::error::Error as StdError;
use std::fmt;

/// Type-erased error, convertible from any standard error.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Downcast to a concrete error type, if this error wraps one.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        self.inner.downcast_ref::<T>()
    }

    /// The root cause chain, starting at this error's inner value.
    pub fn chain(&self) -> Chain<'_> {
        let head: &(dyn StdError + 'static) = self.inner.as_ref();
        Chain { next: Some(head) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the full cause chain, anyhow-style.
            let mut first = true;
            for cause in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                write!(f, "{cause}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.inner)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut causes = self.chain();
        causes.next(); // skip self
        let mut any = false;
        for cause in causes {
            if !any {
                f.write_str("\n\nCaused by:")?;
                any = true;
            }
            write!(f, "\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Iterator over an error's cause chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let current = self.next?;
        self.next = current.source();
        Some(current)
    }
}

/// Plain-message error (what the `anyhow!` macro wraps).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_literal() -> Result<()> {
        bail!("plain message")
    }

    fn fails_fmt(n: usize) -> Result<u32> {
        ensure!(n < 3, "too big: {n}");
        ensure!(n != 2, "exactly {}", n);
        Ok(n as u32)
    }

    fn io_err() -> Result<String> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(String::new())
    }

    #[test]
    fn macros_and_display() {
        assert_eq!(fails_literal().unwrap_err().to_string(), "plain message");
        assert_eq!(fails_fmt(5).unwrap_err().to_string(), "too big: 5");
        assert_eq!(fails_fmt(2).unwrap_err().to_string(), "exactly 2");
        assert_eq!(fails_fmt(1).unwrap(), 1);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_err().unwrap_err();
        assert!(e.to_string().contains("gone"));
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
    }

    #[test]
    fn alternate_display_renders_chain() {
        let e = Error::msg("top");
        assert_eq!(format!("{e:#}"), "top");
        let io = io_err().unwrap_err();
        assert!(format!("{io:#}").contains("gone"));
    }

    #[test]
    fn anyhow_macro_inline_captures() {
        let name = "edge1";
        let e = anyhow!("unknown node {name}");
        assert_eq!(e.to_string(), "unknown node edge1");
        let e2 = anyhow!("{} + {}", 1, 2);
        assert_eq!(e2.to_string(), "1 + 2");
    }
}
