//! Deterministic, seed-driven fault injection (extension beyond the paper).
//!
//! SurveilEdge's eq. 7 allocation and eq. 8–9 threshold adaptation only
//! matter in production if the pipeline keeps answering queries when an
//! edge dies, a link drops frames, or a node slows down. A [`FaultPlan`]
//! scripts those failure modes against simulated time:
//!
//! * **node crash/recover** — [`CrashWindow`]s during which a node accepts
//!   no work and loses its in-flight task,
//! * **link faults** — per-message drop decisions and delivery delays,
//!   decided by a *stateless hash* of `(seed, message, attempt)` so the
//!   outcome is reproducible from the seed alone, independent of thread
//!   interleaving or event-loop ordering,
//! * **slow nodes** — [`SlowWindow`]s multiplying a node's service time.
//!
//! The plan is consumed in three places: the experiment harness
//! (`crate::harness`) replays it inside the DES and reports recovery
//! metrics; the broker (`crate::bus`) accepts it as a [`crate::bus::LinkFault`]
//! to drop published messages in live mode; and [`FaultPlan::script_onto`]
//! schedules the crash/recover timeline onto a [`crate::simclock::Sim`]
//! for bespoke scenarios. Message *reorder* emerges from per-message
//! delivery jitter (two messages with different hashed delays swap order).

use std::sync::{Arc, Mutex};

use crate::simclock::Sim;

/// Heartbeat publish period (seconds) for node liveness (`hb/<node>` keys
/// in the parameter DB).
pub const HB_INTERVAL: f64 = 1.0;

/// A node whose last heartbeat is older than this is treated as dead by
/// the allocator (failover exclusion window: 2.5 heartbeat periods).
pub const HB_STALE_AFTER: f64 = 2.5;

/// Base acknowledgement timeout for a dispatched task (seconds); retries
/// back off exponentially from here.
pub const ACK_TIMEOUT: f64 = 0.25;

/// Dispatch attempts before the sender gives up on the remote path and
/// degrades (edge-local verdict) or falls back to local processing.
pub const MAX_DISPATCH_ATTEMPTS: u32 = 6;

/// Bounded exponential backoff: `ACK_TIMEOUT · 2^min(attempt, 4)`
/// (0.25 s, 0.5 s, 1 s, 2 s, 4 s, 4 s, ...).
pub fn backoff(attempt: u32) -> f64 {
    ACK_TIMEOUT * (1u64 << attempt.min(4)) as f64
}

fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless hash of `(seed, stream, item)` mapped to `[0, 1)`. The same
/// triple always yields the same value — the determinism backbone for
/// per-message fault decisions.
pub fn unit_hash(seed: u64, stream: u64, item: u64) -> f64 {
    let h = mix64(mix64(seed ^ mix64(stream)) ^ item);
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// A node is dead for `t ∈ [from, until)`: it accepts no work, stops
/// heartbeating, and loses whatever it was serving at `from`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrashWindow {
    pub node: u32,
    pub from: f64,
    pub until: f64,
}

/// A node serves `factor`× slower for `t ∈ [from, until)` (factors are
/// clamped to ≥ 1: these model stragglers, not speedups).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlowWindow {
    pub node: u32,
    pub from: f64,
    pub until: f64,
    pub factor: f64,
}

/// A camera-burst window for the overload scenarios: every detection in
/// `t ∈ [from, until)` yields `factor` tasks instead of one. Lives here
/// with the other scripted windows; consumed via
/// `overload::OverloadConfig::burst_factor`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurstWindow {
    pub from: f64,
    pub until: f64,
    pub factor: u32,
}

impl BurstWindow {
    pub fn covers(&self, t: f64) -> bool {
        t >= self.from && t < self.until
    }
}

/// Per-message link fault parameters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkFaults {
    /// Probability a dispatched message is dropped in transit.
    pub drop_p: f64,
    /// Fixed extra delivery delay (seconds) on every delivered message.
    pub delay: f64,
    /// Additional uniform-hashed delay in `[0, jitter)` per message —
    /// nonzero jitter reorders messages.
    pub jitter: f64,
}

/// A complete, reproducible fault schedule. [`Default`] is the empty plan
/// (no faults), which injects nothing and costs nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for every per-message hash decision.
    pub seed: u64,
    pub crashes: Vec<CrashWindow>,
    pub slow: Vec<SlowWindow>,
    pub link: LinkFaults,
}

/// One entry of the scripted fault timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    Crash { node: u32 },
    Recover { node: u32 },
    SlowStart { node: u32, factor: f64 },
    SlowEnd { node: u32 },
}

impl FaultPlan {
    /// The empty plan: injects nothing.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Describe the plan's shape as gauges so an observability export is
    /// self-documenting about what was injected.
    pub fn export_into(&self, reg: &crate::obs::Registry, labels: &[(&str, &str)]) {
        reg.gauge_set("surveiledge_fault_plan_seed", labels, self.seed as f64);
        reg.gauge_set("surveiledge_fault_plan_crash_windows", labels, self.crashes.len() as f64);
        reg.gauge_set("surveiledge_fault_plan_slow_windows", labels, self.slow.len() as f64);
        reg.gauge_set("surveiledge_fault_plan_link_drop_p", labels, self.link.drop_p);
    }

    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.slow.is_empty()
            && self.link == LinkFaults::default()
    }

    /// Is `node` inside any crash window at time `t`?
    pub fn is_down(&self, node: u32, t: f64) -> bool {
        self.crashes.iter().any(|c| c.node == node && t >= c.from && t < c.until)
    }

    /// Recovery time of the crash window covering `(node, t)`, if any
    /// (the latest `until` among overlapping windows).
    pub fn recovery_after(&self, node: u32, t: f64) -> Option<f64> {
        self.crashes
            .iter()
            .filter(|c| c.node == node && t >= c.from && t < c.until)
            .map(|c| c.until)
            .fold(None, |acc, u| Some(acc.map_or(u, |a: f64| a.max(u))))
    }

    /// Service-time multiplier for `node` at `t` (product of active slow
    /// windows; ≥ 1).
    pub fn slowdown(&self, node: u32, t: f64) -> f64 {
        self.slow
            .iter()
            .filter(|s| s.node == node && t >= s.from && t < s.until)
            .map(|s| s.factor.max(1.0))
            .product::<f64>()
            .max(1.0)
    }

    /// Is dispatch attempt `attempt` of message `msg` dropped in transit?
    /// Pure function of `(seed, msg, attempt)` — re-running a scenario
    /// with the same seed reproduces every drop.
    pub fn drops(&self, msg: u64, attempt: u32) -> bool {
        self.link.drop_p > 0.0
            && unit_hash(self.seed, 0xD20F, msg.wrapping_mul(64).wrapping_add(attempt as u64))
                < self.link.drop_p
    }

    /// Extra delivery delay for message `msg` (fixed delay + hashed
    /// jitter). Distinct jitter per message is what reorders deliveries.
    pub fn delay_of(&self, msg: u64) -> f64 {
        self.link.delay + self.link.jitter * unit_hash(self.seed, 0xDE1A, msg)
    }

    /// The crash/slow schedule as a time-sorted event list (stable order
    /// for equal times: crashes before slow windows, declaration order
    /// within each).
    pub fn timeline(&self) -> Vec<(f64, FaultEvent)> {
        let mut out: Vec<(f64, FaultEvent)> = Vec::new();
        for c in &self.crashes {
            if c.until > c.from {
                out.push((c.from, FaultEvent::Crash { node: c.node }));
                out.push((c.until, FaultEvent::Recover { node: c.node }));
            }
        }
        for s in &self.slow {
            if s.until > s.from {
                out.push((s.from, FaultEvent::SlowStart { node: s.node, factor: s.factor }));
                out.push((s.until, FaultEvent::SlowEnd { node: s.node }));
            }
        }
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Script the crash/slow timeline onto a discrete-event simulator:
    /// `handler(sim, t, event)` fires at each scheduled fault transition.
    pub fn script_onto<F>(&self, sim: &mut Sim, handler: F)
    where
        F: FnMut(&mut Sim, f64, FaultEvent) + Send + 'static,
    {
        let h = Arc::new(Mutex::new(handler));
        for (t, ev) in self.timeline() {
            let h = h.clone();
            sim.schedule_at(t, move |s| {
                let mut g = h.lock().unwrap();
                (*g)(s, t, ev);
            });
        }
    }
}

/// Live-mode broker injection: a plan plugged into the bus drops published
/// messages at the plan's link rate (see [`crate::bus::Broker::set_link_fault`]).
impl crate::bus::LinkFault for FaultPlan {
    fn drop_publish(&self, _topic: &str, seq: u64) -> bool {
        self.drops(seq, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_injects_nothing() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.is_down(1, 5.0));
        assert_eq!(p.slowdown(1, 5.0), 1.0);
        for m in 0..1000 {
            assert!(!p.drops(m, 0));
            assert_eq!(p.delay_of(m), 0.0);
        }
        assert!(p.timeline().is_empty());
    }

    #[test]
    fn crash_window_covers_half_open_interval() {
        let p = FaultPlan {
            crashes: vec![CrashWindow { node: 2, from: 10.0, until: 20.0 }],
            ..FaultPlan::default()
        };
        assert!(!p.is_down(2, 9.99));
        assert!(p.is_down(2, 10.0));
        assert!(p.is_down(2, 19.99));
        assert!(!p.is_down(2, 20.0));
        assert!(!p.is_down(1, 15.0), "other nodes unaffected");
        assert_eq!(p.recovery_after(2, 15.0), Some(20.0));
        assert_eq!(p.recovery_after(2, 25.0), None);
    }

    #[test]
    fn overlapping_crashes_recover_at_latest_until() {
        let p = FaultPlan {
            crashes: vec![
                CrashWindow { node: 1, from: 5.0, until: 15.0 },
                CrashWindow { node: 1, from: 10.0, until: 30.0 },
            ],
            ..FaultPlan::default()
        };
        assert_eq!(p.recovery_after(1, 12.0), Some(30.0));
    }

    #[test]
    fn slowdown_is_clamped_product() {
        let p = FaultPlan {
            slow: vec![
                SlowWindow { node: 1, from: 0.0, until: 10.0, factor: 2.0 },
                SlowWindow { node: 1, from: 5.0, until: 10.0, factor: 3.0 },
                SlowWindow { node: 1, from: 0.0, until: 10.0, factor: 0.5 }, // clamped to 1
            ],
            ..FaultPlan::default()
        };
        assert_eq!(p.slowdown(1, 2.0), 2.0);
        assert_eq!(p.slowdown(1, 7.0), 6.0);
        assert_eq!(p.slowdown(1, 11.0), 1.0);
        assert_eq!(p.slowdown(2, 7.0), 1.0);
    }

    #[test]
    fn drops_are_deterministic_and_near_rate() {
        let p = FaultPlan {
            seed: 42,
            link: LinkFaults { drop_p: 0.05, ..LinkFaults::default() },
            ..FaultPlan::default()
        };
        let n = 20_000u64;
        let dropped = (0..n).filter(|&m| p.drops(m, 0)).count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.05).abs() < 0.01, "drop rate {rate}");
        // Determinism: identical plan, identical decisions.
        let q = p.clone();
        for m in 0..1000 {
            assert_eq!(p.drops(m, 0), q.drops(m, 0));
            assert_eq!(p.drops(m, 3), q.drops(m, 3));
        }
    }

    #[test]
    fn different_seeds_give_different_drop_patterns() {
        let mk = |seed| FaultPlan {
            seed,
            link: LinkFaults { drop_p: 0.5, ..LinkFaults::default() },
            ..FaultPlan::default()
        };
        let (a, b) = (mk(1), mk(2));
        let differing = (0..1000u64).filter(|&m| a.drops(m, 0) != b.drops(m, 0)).count();
        assert!(differing > 300, "only {differing}/1000 decisions differ");
    }

    #[test]
    fn retry_attempts_rehash_independently() {
        let p = FaultPlan {
            seed: 7,
            link: LinkFaults { drop_p: 0.5, ..LinkFaults::default() },
            ..FaultPlan::default()
        };
        // A message dropped on attempt 0 is not condemned forever: across
        // many messages, some first-drop messages succeed on retry.
        let rescued = (0..2000u64)
            .filter(|&m| p.drops(m, 0) && !p.drops(m, 1))
            .count();
        assert!(rescued > 200, "rescued {rescued}");
    }

    #[test]
    fn delay_within_bounds_and_jitter_reorders() {
        let p = FaultPlan {
            seed: 9,
            link: LinkFaults { drop_p: 0.0, delay: 0.1, jitter: 0.2 },
            ..FaultPlan::default()
        };
        let mut seen_reorder = false;
        let mut prev = p.delay_of(0);
        for m in 1..200 {
            let d = p.delay_of(m);
            assert!((0.1..0.3 + 1e-12).contains(&d), "delay {d}");
            if d < prev {
                seen_reorder = true;
            }
            prev = d;
        }
        assert!(seen_reorder, "jitter must produce at least one inversion");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff(0), 0.25);
        assert_eq!(backoff(1), 0.5);
        assert_eq!(backoff(2), 1.0);
        assert_eq!(backoff(4), 4.0);
        assert_eq!(backoff(10), 4.0, "backoff is capped");
    }

    #[test]
    fn timeline_is_sorted_and_paired() {
        let p = FaultPlan {
            crashes: vec![
                CrashWindow { node: 2, from: 30.0, until: 40.0 },
                CrashWindow { node: 1, from: 10.0, until: 20.0 },
            ],
            slow: vec![SlowWindow { node: 1, from: 15.0, until: 35.0, factor: 2.0 }],
            ..FaultPlan::default()
        };
        let tl = p.timeline();
        assert_eq!(tl.len(), 6);
        for w in tl.windows(2) {
            assert!(w[0].0 <= w[1].0, "timeline out of order: {tl:?}");
        }
        assert_eq!(tl[0], (10.0, FaultEvent::Crash { node: 1 }));
        assert_eq!(tl[5], (40.0, FaultEvent::Recover { node: 2 }));
    }

    #[test]
    fn script_onto_fires_in_sim_time() {
        let p = FaultPlan {
            crashes: vec![CrashWindow { node: 1, from: 2.0, until: 5.0 }],
            ..FaultPlan::default()
        };
        let mut sim = Sim::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let log = log.clone();
            p.script_onto(&mut sim, move |_, t, ev| log.lock().unwrap().push((t, ev)));
        }
        sim.run_until(10.0);
        let got = log.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![
                (2.0, FaultEvent::Crash { node: 1 }),
                (5.0, FaultEvent::Recover { node: 1 }),
            ]
        );
    }

    #[test]
    fn unit_hash_is_uniform_ish() {
        let n = 10_000u64;
        let mean: f64 = (0..n).map(|i| unit_hash(3, 1, i)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        for i in 0..1000 {
            assert_eq!(unit_hash(3, 1, i), unit_hash(3, 1, i));
        }
    }
}
