//! Time abstraction: real wall-clock vs discrete-event simulated time.
//!
//! The experiment harness runs the full pipeline in two modes (DESIGN.md
//! §7): **live** (real PJRT inference, real sleeping) and **sim**
//! (discrete-event executor with calibrated service times — tractable
//! parameter sweeps on a 1-core host). Both modes drive the *same*
//! scheduler/controller/metric code; only the clock and the classify call
//! differ.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Abstract clock.
pub trait Clock: Send + Sync {
    /// Seconds since scenario start.
    fn now(&self) -> f64;
}

/// Wall-clock implementation (live mode).
pub struct RealClock {
    start: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl RealClock {
    pub fn new() -> RealClock {
        RealClock { start: Instant::now() }
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Keyed event for the DES heap. Ordering: earliest time first, FIFO
/// sequence number as tie-break (deterministic).
struct SimEvent {
    time: f64,
    action: Box<dyn FnOnce(&mut Sim) + Send>,
}

/// Discrete-event simulator: a time-ordered action heap plus the shared
/// simulated "now". Actions schedule further actions.
pub struct Sim {
    now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<HeapKey>>,
    events: Vec<Option<SimEvent>>,
}

#[derive(PartialEq)]
struct HeapKey {
    time: f64,
    seq: u64,
    slot: usize,
}

impl Eq for HeapKey {}

impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim { now: 0.0, seq: 0, heap: BinaryHeap::new(), events: Vec::new() }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `action` at absolute simulated time `at` (clamped to now).
    pub fn schedule_at<F: FnOnce(&mut Sim) + Send + 'static>(&mut self, at: f64, action: F) {
        self.schedule_at_cancellable(at, action);
    }

    /// Like [`Sim::schedule_at`], but returns a token accepted by
    /// [`Sim::cancel`] — the ack-timer primitive: schedule the retry, cancel
    /// it when the acknowledgement arrives first. Tokens are only valid
    /// until the heap fully drains (storage is compacted then).
    pub fn schedule_at_cancellable<F: FnOnce(&mut Sim) + Send + 'static>(
        &mut self,
        at: f64,
        action: F,
    ) -> u64 {
        let time = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let slot = self.events.len();
        self.events.push(Some(SimEvent { time, action: Box::new(action) }));
        self.heap.push(Reverse(HeapKey { time, seq, slot }));
        slot as u64
    }

    /// Schedule after a delay.
    pub fn schedule_in<F: FnOnce(&mut Sim) + Send + 'static>(&mut self, delay: f64, action: F) {
        self.schedule_at(self.now + delay.max(0.0), action);
    }

    /// Cancel a pending event by its token. Returns `true` if the event was
    /// still pending (it will now never run), `false` if it already ran,
    /// was already cancelled, or the token is stale.
    pub fn cancel(&mut self, token: u64) -> bool {
        self.events
            .get_mut(token as usize)
            .and_then(|slot| slot.take())
            .is_some()
    }

    /// Run until the heap empties or simulated time exceeds `until`.
    /// Returns the number of events executed.
    pub fn run_until(&mut self, until: f64) -> usize {
        let mut executed = 0usize;
        while let Some(Reverse(key)) = self.heap.pop() {
            if key.time > until {
                // Put it back for a later run_until call.
                self.heap.push(Reverse(key));
                break;
            }
            if let Some(ev) = self.events[key.slot].take() {
                self.now = ev.time;
                (ev.action)(self);
                executed += 1;
            }
        }
        // Compact storage when fully drained to bound memory across runs.
        if self.heap.is_empty() {
            self.events.clear();
        }
        executed
    }
}

/// A shareable simulated clock view (for code written against [`Clock`]).
#[derive(Clone)]
pub struct SimClockHandle {
    now: Arc<Mutex<f64>>,
}

impl Default for SimClockHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl SimClockHandle {
    pub fn new() -> SimClockHandle {
        SimClockHandle { now: Arc::new(Mutex::new(0.0)) }
    }

    pub fn set(&self, t: f64) {
        *self.now.lock().unwrap() = t;
    }
}

impl Clock for SimClockHandle {
    fn now(&self) -> f64 {
        *self.now.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn real_clock_advances() {
        let c = RealClock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(c.now() > a);
    }

    #[test]
    fn events_run_in_time_order() {
        let mut sim = Sim::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let log = log.clone();
            sim.schedule_at(t, move |_| log.lock().unwrap().push(tag));
        }
        sim.run_until(10.0);
        assert_eq!(*log.lock().unwrap(), vec!['a', 'b', 'c']);
    }

    #[test]
    fn fifo_tiebreak_at_same_time() {
        let mut sim = Sim::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for tag in 0..5 {
            let log = log.clone();
            sim.schedule_at(1.0, move |_| log.lock().unwrap().push(tag));
        }
        sim.run_until(2.0);
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn actions_can_schedule_actions() {
        let mut sim = Sim::new();
        let count = Arc::new(AtomicUsize::new(0));
        fn tick(sim: &mut Sim, count: Arc<AtomicUsize>, remaining: usize) {
            if remaining == 0 {
                return;
            }
            count.fetch_add(1, Ordering::SeqCst);
            sim.schedule_in(1.0, move |s| tick(s, count, remaining - 1));
        }
        let c = count.clone();
        sim.schedule_at(0.0, move |s| tick(s, c, 5));
        sim.run_until(100.0);
        assert_eq!(count.load(Ordering::SeqCst), 5);
        // Ticks run at t=0..4; the terminating no-op lands at t=5.
        assert!((sim.now() - 5.0).abs() < 1e-9, "now {}", sim.now());
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let mut sim = Sim::new();
        let count = Arc::new(AtomicUsize::new(0));
        for t in 0..10 {
            let c = count.clone();
            sim.schedule_at(t as f64, move |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let ran = sim.run_until(4.5);
        assert_eq!(ran, 5); // t = 0..4
        assert_eq!(count.load(Ordering::SeqCst), 5);
        let ran2 = sim.run_until(100.0);
        assert_eq!(ran2, 5);
    }

    #[test]
    fn past_times_clamped_to_now() {
        let mut sim = Sim::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let log = log.clone();
            sim.schedule_at(5.0, move |s| {
                let log2 = log.clone();
                // scheduling "in the past" runs at current time, not before
                s.schedule_at(1.0, move |s2| {
                    log2.lock().unwrap().push(s2.now());
                });
            });
        }
        sim.run_until(10.0);
        assert_eq!(*log.lock().unwrap(), vec![5.0]);
    }

    #[test]
    fn cancelled_event_never_runs() {
        let mut sim = Sim::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c1 = count.clone();
        let token = sim.schedule_at_cancellable(2.0, move |_| {
            c1.fetch_add(1, Ordering::SeqCst);
        });
        let c2 = count.clone();
        sim.schedule_at(3.0, move |_| {
            c2.fetch_add(10, Ordering::SeqCst);
        });
        assert!(sim.cancel(token));
        let ran = sim.run_until(10.0);
        assert_eq!(ran, 1, "only the surviving event executes");
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn cancel_is_idempotent_and_rejects_fired_events() {
        let mut sim = Sim::new();
        let token = sim.schedule_at_cancellable(1.0, |_| {});
        assert!(sim.cancel(token));
        assert!(!sim.cancel(token), "second cancel is a no-op");
        let token2 = sim.schedule_at_cancellable(2.0, |_| {});
        sim.run_until(10.0);
        assert!(!sim.cancel(token2), "already-fired event cannot be cancelled");
    }

    #[test]
    fn ack_before_timeout_cancels_retry() {
        // The dispatch idiom: schedule a retry at now+timeout, cancel it
        // when the ack arrives first.
        let mut sim = Sim::new();
        let retries = Arc::new(AtomicUsize::new(0));
        let r = retries.clone();
        let retry = sim.schedule_at_cancellable(5.0, move |_| {
            r.fetch_add(1, Ordering::SeqCst);
        });
        sim.schedule_at(1.0, move |s| {
            assert!(s.cancel(retry), "ack at t=1 beats the t=5 timeout");
        });
        sim.run_until(10.0);
        assert_eq!(retries.load(Ordering::SeqCst), 0);
        assert!((sim.now() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sim_clock_handle_reflects_set() {
        let h = SimClockHandle::new();
        assert_eq!(h.now(), 0.0);
        h.set(42.5);
        assert_eq!(h.now(), 42.5);
        let h2 = h.clone();
        assert_eq!(h2.now(), 42.5);
    }
}
