//! MQTT-like publish/subscribe message bus (paper: Mosquitto/MQTT is the
//! transport between cameras, edges and the Cloud).
//!
//! In-process broker with MQTT topic semantics:
//! * topic levels separated by `/`,
//! * `+` matches exactly one level, `#` matches the remaining levels,
//! * retained messages are delivered to late subscribers,
//! * QoS 0 (fire and forget; a full subscriber queue evicts its *oldest*
//!   message — freshest-data-wins, counted in
//!   `surveiledge_bus_dropped_total`) and QoS 1 (blocking enqueue —
//!   at-least-once within the process).
//!
//! Nodes exchange three kinds of traffic over it (same topics the paper's
//! prototype uses conceptually): crop uploads (`task/...`), verdicts
//! (`verdict/...`), and parameter-DB replication (`paramdb/...`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A published message. Payloads are opaque bytes; the `meta` map carries
/// small typed fields so hot-path messages avoid serialisation.
#[derive(Clone, Debug)]
pub struct Message {
    pub topic: String,
    pub payload: Arc<Vec<u8>>,
    pub retained: bool,
}

impl Message {
    pub fn new(topic: impl Into<String>, payload: Vec<u8>) -> Message {
        Message { topic: topic.into(), payload: Arc::new(payload), retained: false }
    }

    pub fn retained(topic: impl Into<String>, payload: Vec<u8>) -> Message {
        Message { topic: topic.into(), payload: Arc::new(payload), retained: true }
    }
}

/// Delivery guarantee for a publish call.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum QoS {
    /// Drop if a subscriber queue is full.
    AtMostOnce,
    /// Block until every matching subscriber queue accepts the message.
    AtLeastOnce,
}

/// Does `filter` (with MQTT wildcards) match `topic`?
pub fn topic_matches(filter: &str, topic: &str) -> bool {
    let mut f = filter.split('/');
    let mut t = topic.split('/');
    loop {
        match (f.next(), t.next()) {
            (Some("#"), _) => return true,
            (Some("+"), Some(_)) => continue,
            (Some(fl), Some(tl)) if fl == tl => continue,
            (None, None) => return true,
            _ => return false,
        }
    }
}

/// Transit fault hook for chaos testing: decides, per publish, whether the
/// message is lost before it reaches the broker (as if dropped on the wire).
/// `seq` is the broker's monotonically increasing publish counter, so a
/// deterministic implementation (e.g. [`crate::faults::FaultPlan`]) makes
/// every drop reproducible from its seed.
pub trait LinkFault: Send + Sync {
    fn drop_publish(&self, topic: &str, seq: u64) -> bool;
}

/// Shared state of one subscription's bounded queue. The overflow policy
/// is defined here once: **drop-oldest** for QoS 0 (a camera feed wants
/// the freshest frame, not the stalest), blocking for QoS 1.
struct SubQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct QueueState {
    buf: VecDeque<Message>,
    cap: usize,
    /// Receiver still held? A dropped receiver makes every push fail, so
    /// the broker prunes the subscription.
    rx_alive: bool,
    /// Subscription still registered? Cleared on unsubscribe/prune so a
    /// blocked `recv` wakes up with a disconnect instead of hanging.
    tx_alive: bool,
}

impl SubQueue {
    fn new(cap: usize) -> Arc<SubQueue> {
        Arc::new(SubQueue {
            state: Mutex::new(QueueState {
                buf: VecDeque::new(),
                cap: cap.max(1),
                rx_alive: true,
                tx_alive: true,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        })
    }

    /// QoS-0 push: never blocks. On overflow the *oldest* queued message
    /// is evicted to make room; returns `Ok(evicted_count)` (0 or 1), or
    /// `Err(())` when the receiver is gone.
    fn push_drop_oldest(&self, msg: Message) -> Result<usize, ()> {
        let mut st = self.state.lock().unwrap();
        if !st.rx_alive {
            return Err(());
        }
        let mut evicted = 0;
        if st.buf.len() >= st.cap {
            st.buf.pop_front();
            evicted = 1;
        }
        st.buf.push_back(msg);
        drop(st);
        self.not_empty.notify_one();
        Ok(evicted)
    }

    /// QoS-1 push: blocks until the queue has room (or the receiver is
    /// dropped, which returns `Err(())`).
    fn push_blocking(&self, msg: Message) -> Result<(), ()> {
        let mut st = self.state.lock().unwrap();
        while st.rx_alive && st.buf.len() >= st.cap {
            st = self.not_full.wait(st).unwrap();
        }
        if !st.rx_alive {
            return Err(());
        }
        st.buf.push_back(msg);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }
}

/// `try_recv` failure: nothing queued, or the subscription is gone.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

/// `recv` failure: the subscription was removed and its queue drained.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct RecvError;

/// `recv_timeout` failure.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

/// Receiving end of a subscription (the bus's replacement for
/// `std::sync::mpsc::Receiver`): same `recv` / `try_recv` /
/// `recv_timeout` surface, backed by the broker's bounded drop-oldest
/// queue.
pub struct BusReceiver {
    q: Arc<SubQueue>,
}

impl BusReceiver {
    pub fn try_recv(&self) -> Result<Message, TryRecvError> {
        let mut st = self.q.state.lock().unwrap();
        if let Some(m) = st.buf.pop_front() {
            drop(st);
            self.q.not_full.notify_one();
            return Ok(m);
        }
        if st.tx_alive {
            Err(TryRecvError::Empty)
        } else {
            Err(TryRecvError::Disconnected)
        }
    }

    pub fn recv(&self) -> Result<Message, RecvError> {
        let mut st = self.q.state.lock().unwrap();
        loop {
            if let Some(m) = st.buf.pop_front() {
                drop(st);
                self.q.not_full.notify_one();
                return Ok(m);
            }
            if !st.tx_alive {
                return Err(RecvError);
            }
            st = self.q.not_empty.wait(st).unwrap();
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<Message, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.q.state.lock().unwrap();
        loop {
            if let Some(m) = st.buf.pop_front() {
                drop(st);
                self.q.not_full.notify_one();
                return Ok(m);
            }
            if !st.tx_alive {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _) = self.q.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

impl Drop for BusReceiver {
    fn drop(&mut self) {
        let mut st = self.q.state.lock().unwrap();
        st.rx_alive = false;
        drop(st);
        // Wake blocked QoS-1 publishers so they error out and prune.
        self.q.not_full.notify_all();
    }
}

struct Subscription {
    filter: String,
    queue: Arc<SubQueue>,
    id: u64,
}

impl Drop for Subscription {
    fn drop(&mut self) {
        let mut st = self.queue.state.lock().unwrap();
        st.tx_alive = false;
        drop(st);
        // Wake a blocked `recv` so it sees the disconnect.
        self.queue.not_empty.notify_all();
    }
}

struct BrokerInner {
    subs: Mutex<Vec<Subscription>>,
    retained: Mutex<HashMap<String, Message>>,
    next_id: Mutex<u64>,
    stats: Mutex<BusStats>,
    fault: Mutex<Option<Arc<dyn LinkFault>>>,
    pub_seq: AtomicU64,
    obs: Mutex<Option<crate::obs::Registry>>,
}

/// Broker throughput counters (observability + bandwidth accounting).
#[derive(Clone, Copy, Default, Debug)]
pub struct BusStats {
    pub published: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub bytes: u64,
    /// Publishes swallowed by an installed [`LinkFault`] (chaos testing).
    pub injected_drops: u64,
}

/// The in-process broker. Cheap to clone; all clones share state.
#[derive(Clone)]
pub struct Broker {
    inner: Arc<BrokerInner>,
}

impl Default for Broker {
    fn default() -> Self {
        Self::new()
    }
}

impl Broker {
    pub fn new() -> Broker {
        Broker {
            inner: Arc::new(BrokerInner {
                subs: Mutex::new(Vec::new()),
                retained: Mutex::new(HashMap::new()),
                next_id: Mutex::new(1),
                stats: Mutex::new(BusStats::default()),
                fault: Mutex::new(None),
                pub_seq: AtomicU64::new(0),
                obs: Mutex::new(None),
            }),
        }
    }

    /// Mirror broker throughput into a metric registry
    /// (`surveiledge_bus_*_total` counters, updated on every publish).
    pub fn attach_registry(&self, reg: crate::obs::Registry) {
        *self.inner.obs.lock().unwrap() = Some(reg);
    }

    /// Install a transit fault: subsequent publishes consult it and may be
    /// dropped before reaching any subscriber (counted in
    /// [`BusStats::injected_drops`]).
    pub fn set_link_fault(&self, fault: Arc<dyn LinkFault>) {
        *self.inner.fault.lock().unwrap() = Some(fault);
    }

    /// Remove an installed transit fault; delivery returns to normal.
    pub fn clear_link_fault(&self) {
        *self.inner.fault.lock().unwrap() = None;
    }

    /// Subscribe with a bounded queue; returns the receiving end and the
    /// subscription id (for unsubscribe). Retained messages matching the
    /// filter are delivered immediately. Queue overflow is drop-oldest
    /// (see [`SubQueue`]); evictions land in [`BusStats::dropped`] and
    /// `surveiledge_bus_dropped_total`.
    pub fn subscribe(&self, filter: &str, capacity: usize) -> (BusReceiver, u64) {
        let q = SubQueue::new(capacity);
        let id = {
            let mut next = self.inner.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        // Deliver retained state first.
        {
            let retained = self.inner.retained.lock().unwrap();
            for (topic, msg) in retained.iter() {
                if topic_matches(filter, topic) {
                    let _ = q.push_drop_oldest(msg.clone());
                }
            }
        }
        self.inner.subs.lock().unwrap().push(Subscription {
            filter: filter.to_string(),
            queue: q.clone(),
            id,
        });
        (BusReceiver { q }, id)
    }

    pub fn unsubscribe(&self, id: u64) {
        self.inner.subs.lock().unwrap().retain(|s| s.id != id);
    }

    /// Publish; returns the number of subscribers the message reached.
    /// An installed [`LinkFault`] may swallow the message first — a faulted
    /// publish reaches nobody and does not update retained state (the wire
    /// lost it before the broker ever saw it).
    pub fn publish(&self, msg: Message, qos: QoS) -> usize {
        let seq = self.inner.pub_seq.fetch_add(1, Ordering::Relaxed);
        let faulted = {
            let fault = self.inner.fault.lock().unwrap();
            fault.as_ref().is_some_and(|f| f.drop_publish(&msg.topic, seq))
        };
        if faulted {
            {
                let mut stats = self.inner.stats.lock().unwrap();
                stats.published += 1;
                stats.injected_drops += 1;
            }
            if let Some(reg) = self.inner.obs.lock().unwrap().as_ref() {
                reg.inc("surveiledge_bus_published_total", &[], 1);
                reg.inc("surveiledge_bus_injected_drops_total", &[], 1);
            }
            return 0;
        }
        if msg.retained {
            self.inner
                .retained
                .lock()
                .unwrap()
                .insert(msg.topic.clone(), msg.clone());
        }
        let mut delivered = 0usize;
        let mut dropped = 0usize;
        let mut dead: Vec<u64> = Vec::new();
        // Snapshot matching senders, then send with the registry lock
        // RELEASED: a blocking QoS-1 send into a full queue must never
        // prevent other threads from publishing (deadlock otherwise: a
        // consumer that needs to publish its own result to make progress
        // would wait on the registry lock forever).
        let targets: Vec<(u64, Arc<SubQueue>)> = {
            let subs = self.inner.subs.lock().unwrap();
            subs.iter()
                .filter(|s| topic_matches(&s.filter, &msg.topic))
                .map(|s| (s.id, s.queue.clone()))
                .collect()
        };
        for (id, q) in targets {
            match qos {
                // QoS 0 overflow = drop-oldest: the new message always
                // lands; the evicted one counts as dropped.
                QoS::AtMostOnce => match q.push_drop_oldest(msg.clone()) {
                    Ok(evicted) => {
                        delivered += 1;
                        dropped += evicted;
                    }
                    Err(()) => dead.push(id),
                },
                QoS::AtLeastOnce => match q.push_blocking(msg.clone()) {
                    Ok(()) => delivered += 1,
                    Err(()) => dead.push(id),
                },
            }
        }
        if !dead.is_empty() {
            let mut subs = self.inner.subs.lock().unwrap();
            subs.retain(|s| !dead.contains(&s.id));
        }
        let bytes = msg.payload.len() as u64 * delivered.max(1) as u64;
        {
            let mut stats = self.inner.stats.lock().unwrap();
            stats.published += 1;
            stats.delivered += delivered as u64;
            stats.dropped += dropped as u64;
            stats.bytes += bytes;
        }
        if let Some(reg) = self.inner.obs.lock().unwrap().as_ref() {
            reg.inc("surveiledge_bus_published_total", &[], 1);
            reg.inc("surveiledge_bus_delivered_total", &[], delivered as u64);
            reg.inc("surveiledge_bus_dropped_total", &[], dropped as u64);
            reg.inc("surveiledge_bus_bytes_total", &[], bytes);
        }
        delivered
    }

    pub fn stats(&self) -> BusStats {
        *self.inner.stats.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;
    use std::time::Duration;

    #[test]
    fn exact_topic_delivery() {
        let b = Broker::new();
        let (rx, _) = b.subscribe("task/edge1", 8);
        b.publish(Message::new("task/edge1", vec![1, 2, 3]), QoS::AtLeastOnce);
        b.publish(Message::new("task/edge2", vec![9]), QoS::AtLeastOnce);
        let m = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.payload.as_slice(), &[1, 2, 3]);
        assert!(rx.try_recv().is_err(), "must not receive other topics");
    }

    #[test]
    fn registry_mirrors_bus_counters() {
        let b = Broker::new();
        let reg = crate::obs::Registry::new();
        b.attach_registry(reg.clone());
        let (_rx, _) = b.subscribe("task/#", 8);
        b.publish(Message::new("task/edge1", vec![0; 16]), QoS::AtLeastOnce);
        assert_eq!(reg.counter("surveiledge_bus_published_total", &[]), 1);
        assert_eq!(reg.counter("surveiledge_bus_delivered_total", &[]), 1);
        assert_eq!(reg.counter("surveiledge_bus_bytes_total", &[]), 16);
        assert_eq!(reg.counter("surveiledge_bus_dropped_total", &[]), 0);
    }

    #[test]
    fn wildcard_matching() {
        assert!(topic_matches("task/+", "task/edge1"));
        assert!(!topic_matches("task/+", "task/edge1/crop"));
        assert!(topic_matches("task/#", "task/edge1/crop"));
        assert!(topic_matches("#", "anything/at/all"));
        assert!(topic_matches("a/+/c", "a/b/c"));
        assert!(!topic_matches("a/+/c", "a/b/d"));
        assert!(!topic_matches("a/b", "a"));
        assert!(!topic_matches("a", "a/b"));
        assert!(topic_matches("a/b", "a/b"));
    }

    #[test]
    fn query_result_topic_matching() {
        // The query subsystem streams verdicts on `query/<id>/results`;
        // dashboards watch one query, all queries, or a query's whole
        // subtree (results + admitted/retired control messages).
        assert!(topic_matches("query/amber-moped/results", "query/amber-moped/results"));
        assert!(topic_matches("query/+/results", "query/amber-moped/results"));
        assert!(topic_matches("query/#", "query/amber-moped/results"));
        assert!(topic_matches("query/amber-moped/#", "query/amber-moped/results"));
        assert!(topic_matches("query/amber-moped/#", "query/amber-moped/admitted"));
        // A single `+` never spans the id *and* the suffix level.
        assert!(!topic_matches("query/+", "query/amber-moped/results"));
        // One query's filter must not see another query's stream.
        assert!(!topic_matches("query/amber-moped/results", "query/person-watch/results"));
        assert!(!topic_matches("query/amber-moped/#", "query/person-watch/results"));
        // Dashed ids are one level: `-` is not a separator.
        assert!(topic_matches("query/+/results", "query/q0/results"));
        assert!(!topic_matches("query/amber/+/results", "query/amber-moped/results"));
    }

    #[test]
    fn prop_query_topic_matches_agree_with_reference() {
        // Same oracle comparison as `prop_topic_matches_agrees_with_reference`,
        // but over the query subsystem's topic shape (`query/<id>/<kind>`)
        // so id-level wildcards get dense coverage.
        check("query_topic_matches_vs_reference", |rng, _| {
            let ids = ["amber-moped", "person-watch", "q0", "q1"];
            let kinds = ["results", "admitted", "retired"];
            let topic = [
                "query",
                ids[rng.range_usize(0, ids.len())],
                kinds[rng.range_usize(0, kinds.len())],
            ];
            let fid = ["amber-moped", "person-watch", "q0", "q1", "+", "#"];
            let fkind = ["results", "admitted", "retired", "+", "#"];
            let mut filter = vec!["query"];
            let id = fid[rng.range_usize(0, fid.len())];
            filter.push(id);
            if id != "#" && rng.range_usize(0, 4) > 0 {
                filter.push(fkind[rng.range_usize(0, fkind.len())]);
            }
            let got = topic_matches(&filter.join("/"), &topic.join("/"));
            let want = reference_matches(&filter, &topic);
            assert_eq!(got, want, "filter {filter:?} vs topic {topic:?}");
        });
    }

    #[test]
    fn multi_subscriber_fanout_preserves_publish_order() {
        // N subscribers with overlapping filters over the per-query result
        // topics: each must receive exactly its matching messages, in
        // publish order (the broker walks subscriptions per publish, so
        // per-subscriber order equals global publish order).
        let b = Broker::new();
        let (rx_all, _) = b.subscribe("query/+/results", 256);
        let (rx_tree, _) = b.subscribe("query/#", 256);
        let (rx_q0, _) = b.subscribe("query/q0/results", 256);
        let (rx_q1, _) = b.subscribe("query/q1/results", 256);
        let mut published = Vec::new();
        for i in 0..60u8 {
            let id = format!("q{}", i % 3);
            b.publish(Message::new(format!("query/{id}/results"), vec![i]), QoS::AtLeastOnce);
            published.push((id, i));
        }
        let drain = |rx: &BusReceiver| -> Vec<u8> {
            let mut got = Vec::new();
            while let Ok(m) = rx.try_recv() {
                got.push(m.payload[0]);
            }
            got
        };
        let want = |pred: &dyn Fn(&str) -> bool| -> Vec<u8> {
            published.iter().filter(|(id, _)| pred(id)).map(|&(_, i)| i).collect()
        };
        assert_eq!(drain(&rx_all), want(&|_| true), "query/+/results sees every stream in order");
        assert_eq!(drain(&rx_tree), want(&|_| true), "query/# sees every stream in order");
        assert_eq!(drain(&rx_q0), want(&|id| id == "q0"), "exact filter sees only its query");
        assert_eq!(drain(&rx_q1), want(&|id| id == "q1"), "exact filter sees only its query");
    }

    #[test]
    fn plus_wildcard_receives_all_edges() {
        let b = Broker::new();
        let (rx, _) = b.subscribe("verdict/+", 16);
        for i in 0..3 {
            b.publish(Message::new(format!("verdict/edge{i}"), vec![i]), QoS::AtLeastOnce);
        }
        let got: Vec<u8> = (0..3).map(|_| rx.recv().unwrap().payload[0]).collect();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn retained_delivered_to_late_subscriber() {
        let b = Broker::new();
        b.publish(Message::retained("cfg/alpha", vec![80]), QoS::AtLeastOnce);
        let (rx, _) = b.subscribe("cfg/#", 4);
        let m = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(m.payload.as_slice(), &[80]);
    }

    #[test]
    fn retained_overwritten_by_topic() {
        let b = Broker::new();
        b.publish(Message::retained("cfg/alpha", vec![1]), QoS::AtLeastOnce);
        b.publish(Message::retained("cfg/alpha", vec![2]), QoS::AtLeastOnce);
        let (rx, _) = b.subscribe("cfg/alpha", 4);
        assert_eq!(rx.recv().unwrap().payload.as_slice(), &[2]);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn qos0_overflow_is_drop_oldest() {
        // The overflow contract, pinned: a QoS-0 publish into a full
        // queue evicts the *oldest* queued message and delivers the new
        // one — freshest data wins, and the eviction is counted.
        let b = Broker::new();
        let reg = crate::obs::Registry::new();
        b.attach_registry(reg.clone());
        let (rx, _) = b.subscribe("x", 2);
        assert_eq!(b.publish(Message::new("x", vec![1]), QoS::AtMostOnce), 1);
        assert_eq!(b.publish(Message::new("x", vec![2]), QoS::AtMostOnce), 1);
        // Queue full: this publish still lands; [1] is evicted.
        assert_eq!(b.publish(Message::new("x", vec![3]), QoS::AtMostOnce), 1);
        assert_eq!(b.stats().dropped, 1);
        assert_eq!(reg.counter("surveiledge_bus_dropped_total", &[]), 1);
        let got: Vec<u8> = [rx.try_recv().unwrap(), rx.try_recv().unwrap()]
            .iter()
            .map(|m| m.payload[0])
            .collect();
        assert_eq!(got, vec![2, 3], "oldest evicted, order of survivors preserved");
        assert_eq!(rx.try_recv().unwrap_err(), TryRecvError::Empty);
    }

    #[test]
    fn recv_sees_disconnect_after_unsubscribe() {
        let b = Broker::new();
        let (rx, id) = b.subscribe("t", 4);
        b.publish(Message::new("t", vec![7]), QoS::AtLeastOnce);
        b.unsubscribe(id);
        // Queued messages still drain, then the disconnect surfaces.
        assert_eq!(rx.recv().unwrap().payload[0], 7);
        assert_eq!(rx.recv().unwrap_err(), RecvError);
        assert_eq!(rx.try_recv().unwrap_err(), TryRecvError::Disconnected);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)).unwrap_err(),
            RecvTimeoutError::Disconnected
        );
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let b = Broker::new();
        let (rx, id) = b.subscribe("t", 4);
        b.unsubscribe(id);
        assert_eq!(b.publish(Message::new("t", vec![1]), QoS::AtLeastOnce), 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn dead_subscriber_pruned() {
        let b = Broker::new();
        {
            let (_rx, _) = b.subscribe("t", 4);
            // _rx dropped here
        }
        assert_eq!(b.publish(Message::new("t", vec![1]), QoS::AtLeastOnce), 0);
        // Second publish should find zero subscriptions (pruned).
        assert_eq!(b.publish(Message::new("t", vec![2]), QoS::AtLeastOnce), 0);
    }

    #[test]
    fn blocked_qos1_publish_does_not_block_other_publishers() {
        // Regression test for the consumer-produces-too deadlock: thread A
        // blocks on a full QoS-1 queue; thread B must still be able to
        // publish (and by consuming A's topic, unblock A).
        let b = Broker::new();
        let (rx_full, _) = b.subscribe("full", 1);
        b.publish(Message::new("full", vec![0]), QoS::AtLeastOnce); // fills it
        let blocker = {
            let b = b.clone();
            std::thread::spawn(move || {
                // Blocks until rx_full is drained.
                b.publish(Message::new("full", vec![1]), QoS::AtLeastOnce)
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        // B: publish to an unrelated topic — must complete immediately.
        let (rx_other, _) = b.subscribe("other", 4);
        let n = b.publish(Message::new("other", vec![2]), QoS::AtLeastOnce);
        assert_eq!(n, 1);
        assert_eq!(rx_other.recv_timeout(Duration::from_secs(1)).unwrap().payload[0], 2);
        // Drain the full queue; the blocked publisher finishes.
        assert_eq!(rx_full.recv_timeout(Duration::from_secs(1)).unwrap().payload[0], 0);
        assert_eq!(blocker.join().unwrap(), 1);
        assert_eq!(rx_full.recv_timeout(Duration::from_secs(1)).unwrap().payload[0], 1);
    }

    #[test]
    fn cross_thread_delivery() {
        let b = Broker::new();
        let (rx, _) = b.subscribe("work/#", 64);
        let pubber = {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..50u8 {
                    b.publish(Message::new(format!("work/{i}"), vec![i]), QoS::AtLeastOnce);
                }
            })
        };
        let mut got = Vec::new();
        for _ in 0..50 {
            got.push(rx.recv_timeout(Duration::from_secs(2)).unwrap().payload[0]);
        }
        pubber.join().unwrap();
        assert_eq!(got.len(), 50);
    }

    struct DropEven;
    impl LinkFault for DropEven {
        fn drop_publish(&self, _topic: &str, seq: u64) -> bool {
            seq % 2 == 0
        }
    }

    #[test]
    fn link_fault_swallows_publishes_deterministically() {
        let b = Broker::new();
        let (rx, _) = b.subscribe("t", 64);
        b.set_link_fault(Arc::new(DropEven));
        let mut reached = 0;
        for i in 0..10u8 {
            reached += b.publish(Message::new("t", vec![i]), QoS::AtLeastOnce);
        }
        assert_eq!(reached, 5, "even seqs (0,2,4,6,8) must be swallowed");
        let got: Vec<u8> = (0..5).map(|_| rx.recv().unwrap().payload[0]).collect();
        assert_eq!(got, vec![1, 3, 5, 7, 9]);
        let s = b.stats();
        assert_eq!(s.published, 10);
        assert_eq!(s.injected_drops, 5);
        assert_eq!(s.delivered, 5);
    }

    #[test]
    fn faulted_retained_publish_is_not_stored() {
        let b = Broker::new();
        b.set_link_fault(Arc::new(DropEven)); // seq 0 dropped
        b.publish(Message::retained("cfg/alpha", vec![1]), QoS::AtLeastOnce);
        b.clear_link_fault();
        let (rx, _) = b.subscribe("cfg/alpha", 4);
        assert!(rx.try_recv().is_err(), "a message lost on the wire must not retain");
        // Delivery back to normal after clear.
        assert_eq!(b.publish(Message::new("cfg/alpha", vec![2]), QoS::AtLeastOnce), 1);
    }

    #[test]
    fn fault_plan_drops_near_rate_and_reproducibly() {
        use crate::faults::{FaultPlan, LinkFaults};
        let plan = Arc::new(FaultPlan {
            seed: 42,
            link: LinkFaults { drop_p: 0.25, ..LinkFaults::default() },
            ..FaultPlan::default()
        });
        let run = || {
            let b = Broker::new();
            b.set_link_fault(plan.clone());
            let (_rx, _) = b.subscribe("chaos", 4096);
            for i in 0..2000u16 {
                b.publish(Message::new("chaos", i.to_le_bytes().to_vec()), QoS::AtLeastOnce);
            }
            b.stats().injected_drops
        };
        let dropped = run();
        let rate = dropped as f64 / 2000.0;
        assert!((rate - 0.25).abs() < 0.05, "drop rate {rate}");
        assert_eq!(run(), dropped, "same plan + same publish order = same drops");
    }

    /// Obviously-correct recursive matcher used as the property-test oracle
    /// for the iterator-based [`topic_matches`].
    fn reference_matches(filter: &[&str], topic: &[&str]) -> bool {
        match (filter.split_first(), topic.split_first()) {
            (Some((&"#", _)), _) => true,
            (Some((&"+", _)), Some((_, tr))) => reference_matches(&filter[1..], tr),
            (Some((&fl, _)), Some((&tl, tr))) if fl == tl => reference_matches(&filter[1..], tr),
            (None, None) => true,
            _ => false,
        }
    }

    #[test]
    fn prop_topic_matches_agrees_with_reference() {
        check("topic_matches_vs_reference", |rng, _| {
            // Random topics and filters over a tiny alphabet so collisions
            // (and thus true matches) are common.
            let levels = ["a", "b", "c", "+", "#"];
            let topic_levels = ["a", "b", "c"];
            let fdepth = rng.range_usize(1, 5);
            let tdepth = rng.range_usize(1, 5);
            let filter: Vec<&str> = (0..fdepth).map(|_| levels[rng.range_usize(0, levels.len())]).collect();
            let topic: Vec<&str> = (0..tdepth).map(|_| topic_levels[rng.range_usize(0, topic_levels.len())]).collect();
            let got = topic_matches(&filter.join("/"), &topic.join("/"));
            let want = reference_matches(&filter, &topic);
            assert_eq!(got, want, "filter {filter:?} vs topic {topic:?}");
        });
    }

    #[test]
    fn prop_wildcard_matches_are_consistent() {
        check("topic_wildcards", |rng, _| {
            let depth = rng.range_usize(1, 5);
            let topic: Vec<String> = (0..depth).map(|i| format!("l{}", rng.range_usize(0, 3) + i)).collect();
            let topic_str = topic.join("/");
            // Exact filter always matches.
            assert!(topic_matches(&topic_str, &topic_str));
            // Replacing any single level with + still matches.
            for i in 0..depth {
                let mut f = topic.clone();
                f[i] = "+".into();
                assert!(topic_matches(&f.join("/"), &topic_str));
            }
            // Truncating to a prefix + "#" matches.
            for i in 0..depth {
                let mut f: Vec<String> = topic[..i].to_vec();
                f.push("#".into());
                assert!(topic_matches(&f.join("/"), &topic_str));
            }
            // A filter with an extra level does not match.
            let mut longer = topic.clone();
            longer.push("zzz".into());
            assert!(!topic_matches(&longer.join("/"), &topic_str));
        });
    }
}
pub mod tcp;
