//! TCP transport for the bus: a length-prefixed frame protocol that
//! bridges a remote client into the in-process [`super::Broker`] — the
//! deployment shape the paper uses (Mosquitto over TCP between edges and
//! the Cloud).
//!
//! Frame layout (little-endian):
//!   u8   kind        (0 = SUB, 1 = PUB, 2 = PUB-retained, 3 = PING)
//!   u16  topic_len   topic bytes follow
//!   u32  payload_len payload bytes follow (PUB only)
//!
//! A client SUBscribes with a filter, then receives PUB frames for every
//! matching message; PUBs from the client are forwarded into the broker.
//! QoS over TCP is at-most-once (the transport buffers; local subscribers
//! keep their configured QoS).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::{Broker, Message, QoS};

/// Frame kinds.
pub const KIND_SUB: u8 = 0;
pub const KIND_PUB: u8 = 1;
pub const KIND_PUB_RETAINED: u8 = 2;
pub const KIND_PING: u8 = 3;

/// Encode one frame.
pub fn encode_frame(kind: u8, topic: &str, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(7 + topic.len() + payload.len());
    out.push(kind);
    out.extend_from_slice(&(topic.len() as u16).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(topic.as_bytes());
    out.extend_from_slice(payload);
    out
}

/// Read one frame from a stream. Returns `None` on clean EOF.
pub fn read_frame(stream: &mut impl Read) -> crate::Result<Option<(u8, String, Vec<u8>)>> {
    let mut header = [0u8; 7];
    match stream.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let kind = header[0];
    let topic_len = u16::from_le_bytes([header[1], header[2]]) as usize;
    let payload_len = u32::from_le_bytes([header[3], header[4], header[5], header[6]]) as usize;
    anyhow::ensure!(topic_len <= 4096, "oversized topic ({topic_len})");
    anyhow::ensure!(payload_len <= 64 << 20, "oversized payload ({payload_len})");
    let mut topic = vec![0u8; topic_len];
    stream.read_exact(&mut topic)?;
    let mut payload = vec![0u8; payload_len];
    stream.read_exact(&mut payload)?;
    Ok(Some((kind, String::from_utf8(topic)?, payload)))
}

/// A broker exposed over TCP. Accepts any number of clients; each client
/// may SUB once (more SUBs add filters) and PUB freely.
pub struct TcpBridge {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpBridge {
    /// Serve `broker` on 127.0.0.1:<port> (0 = ephemeral).
    pub fn serve(broker: Broker, port: u16) -> crate::Result<TcpBridge> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("bus-tcp-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let broker = broker.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new()
                                .name("bus-tcp-client".into())
                                .spawn(move || client_loop(stream, broker, stop3));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(TcpBridge { addr, stop, accept_thread: Some(accept_thread) })
    }
}

impl Drop for TcpBridge {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn client_loop(stream: TcpStream, broker: Broker, stop: Arc<AtomicBool>) {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = reader.set_read_timeout(Some(Duration::from_millis(100)));
    let writer = Arc::new(std::sync::Mutex::new(stream));
    let mut forwarders: Vec<JoinHandle<()>> = Vec::new();

    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let frame = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                // timeouts surface as io errors; keep polling on timeout
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                break;
            }
        };
        match frame {
            (KIND_SUB, filter, _) => {
                // Forward matching broker traffic to this client.
                let (rx, _id) = broker.subscribe(&filter, 1024);
                let writer = writer.clone();
                let stop = stop.clone();
                forwarders.push(std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match rx.recv_timeout(Duration::from_millis(100)) {
                            Ok(msg) => {
                                let kind = if msg.retained { KIND_PUB_RETAINED } else { KIND_PUB };
                                let frame = encode_frame(kind, &msg.topic, &msg.payload);
                                let mut w = writer.lock().unwrap();
                                if w.write_all(&frame).is_err() {
                                    break;
                                }
                            }
                            Err(super::RecvTimeoutError::Timeout) => continue,
                            Err(_) => break,
                        }
                    }
                }));
            }
            (KIND_PUB, topic, payload) => {
                broker.publish(Message::new(topic, payload), QoS::AtMostOnce);
            }
            (KIND_PUB_RETAINED, topic, payload) => {
                broker.publish(Message::retained(topic, payload), QoS::AtMostOnce);
            }
            (KIND_PING, _, _) => {
                let mut w = writer.lock().unwrap();
                let _ = w.write_all(&encode_frame(KIND_PING, "", &[]));
            }
            _ => break, // unknown frame: drop the client
        }
    }
}

/// Client side: connect, subscribe, publish, receive.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: SocketAddr) -> crate::Result<TcpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(TcpClient { stream })
    }

    pub fn subscribe(&mut self, filter: &str) -> crate::Result<()> {
        self.stream.write_all(&encode_frame(KIND_SUB, filter, &[]))?;
        Ok(())
    }

    pub fn publish(&mut self, topic: &str, payload: &[u8]) -> crate::Result<()> {
        self.stream.write_all(&encode_frame(KIND_PUB, topic, payload))?;
        Ok(())
    }

    pub fn publish_retained(&mut self, topic: &str, payload: &[u8]) -> crate::Result<()> {
        self.stream
            .write_all(&encode_frame(KIND_PUB_RETAINED, topic, payload))?;
        Ok(())
    }

    /// Blocking receive of the next PUB frame addressed to this client.
    pub fn recv(&mut self, timeout: Duration) -> crate::Result<Option<(String, Vec<u8>)>> {
        self.stream.set_read_timeout(Some(timeout))?;
        loop {
            match read_frame(&mut self.stream) {
                Ok(Some((KIND_PUB | KIND_PUB_RETAINED, topic, payload))) => {
                    return Ok(Some((topic, payload)));
                }
                Ok(Some((KIND_PING, _, _))) => continue,
                Ok(Some(_)) => continue,
                Ok(None) => return Ok(None),
                Err(e) => {
                    if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            ioe.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) {
                            return Ok(None);
                        }
                    }
                    return Err(e);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let frame = encode_frame(KIND_PUB, "a/b", &[1, 2, 3]);
        let mut cursor = std::io::Cursor::new(frame);
        let (kind, topic, payload) = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(kind, KIND_PUB);
        assert_eq!(topic, "a/b");
        assert_eq!(payload, vec![1, 2, 3]);
    }

    #[test]
    fn frame_eof_is_none() {
        let mut cursor = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn frame_rejects_oversized() {
        let mut bad = vec![KIND_PUB];
        bad.extend_from_slice(&8000u16.to_le_bytes()); // oversized topic
        bad.extend_from_slice(&0u32.to_le_bytes());
        let mut cursor = std::io::Cursor::new(bad);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn tcp_pub_to_local_subscriber() {
        let broker = Broker::new();
        let (rx, _) = broker.subscribe("remote/#", 16);
        let bridge = TcpBridge::serve(broker, 0).unwrap();
        let mut client = TcpClient::connect(bridge.addr).unwrap();
        client.publish("remote/sensor", b"hello").unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(msg.topic, "remote/sensor");
        assert_eq!(msg.payload.as_slice(), b"hello");
    }

    #[test]
    fn tcp_subscriber_receives_local_pub() {
        let broker = Broker::new();
        let bridge = TcpBridge::serve(broker.clone(), 0).unwrap();
        let mut client = TcpClient::connect(bridge.addr).unwrap();
        client.subscribe("verdict/+").unwrap();
        // Give the SUB frame time to register before publishing.
        std::thread::sleep(Duration::from_millis(100));
        broker.publish(Message::new("verdict/edge1", vec![42]), QoS::AtMostOnce);
        let got = client.recv(Duration::from_secs(2)).unwrap();
        let (topic, payload) = got.expect("expected a PUB frame");
        assert_eq!(topic, "verdict/edge1");
        assert_eq!(payload, vec![42]);
    }

    #[test]
    fn tcp_two_clients_exchange() {
        let broker = Broker::new();
        let bridge = TcpBridge::serve(broker, 0).unwrap();
        let mut sub = TcpClient::connect(bridge.addr).unwrap();
        sub.subscribe("chat").unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let mut publ = TcpClient::connect(bridge.addr).unwrap();
        publ.publish("chat", b"over tcp").unwrap();
        let (topic, payload) = sub.recv(Duration::from_secs(2)).unwrap().expect("msg");
        assert_eq!(topic, "chat");
        assert_eq!(payload, b"over tcp");
    }

    #[test]
    fn tcp_retained_flag_preserved() {
        let broker = Broker::new();
        let bridge = TcpBridge::serve(broker.clone(), 0).unwrap();
        let mut client = TcpClient::connect(bridge.addr).unwrap();
        client.publish_retained("cfg/alpha", &[8]).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // A late local subscriber still sees it (retained semantics).
        let (rx, _) = broker.subscribe("cfg/alpha", 4);
        let msg = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(msg.payload.as_slice(), &[8]);
        assert!(msg.retained);
    }
}
