//! TOML-subset parser (see module docs in `config/mod.rs`).

use std::collections::HashMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One `[[name]]` array-of-tables block: its keys in document order.
#[derive(Clone, Debug)]
pub struct TomlBlock {
    name: String,
    entries: Vec<(String, TomlValue)>,
}

impl TomlBlock {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn get_str(&self, key: &str) -> Option<String> {
        self.get(key).and_then(|v| v.as_str().map(str::to_string))
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(TomlValue::as_f64)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(TomlValue::as_i64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(TomlValue::as_bool)
    }

    pub fn get_i64_array(&self, key: &str) -> Option<Vec<i64>> {
        match self.get(key)? {
            TomlValue::Array(items) => items.iter().map(TomlValue::as_i64).collect(),
            _ => None,
        }
    }

    /// Keys present in this block, in document order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }

    /// Reject any key outside `allowed` with a named-key error (`ctx`
    /// names the block for the message, e.g. `[[query]] block 2 ("q1")`).
    pub fn ensure_keys(&self, ctx: &str, allowed: &[&str]) -> crate::Result<()> {
        for k in self.keys() {
            anyhow::ensure!(
                allowed.contains(&k),
                "{ctx}: unknown key {k:?} (expected one of: {})",
                allowed.join(", ")
            );
        }
        Ok(())
    }
}

/// A parsed document: `(section, key) -> value` for `[section]` tables
/// (top-level keys use the empty section name), plus `[[name]]`
/// array-of-tables blocks in document order.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: HashMap<(String, String), TomlValue>,
    blocks: Vec<TomlBlock>,
}

/// Where the next `key = value` line lands while parsing.
enum Target {
    Section(String),
    Block(usize),
}

impl TomlDoc {
    pub fn parse(text: &str) -> crate::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut target = Target::Section(String::new());
        let valid_name = |s: &str| {
            !s.is_empty() && s.chars().all(|c| c.is_alphanumeric() || "._-".contains(c))
        };
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated block header", lineno + 1))?
                    .trim();
                anyhow::ensure!(valid_name(name), "line {}: bad block name {name:?}", lineno + 1);
                doc.blocks.push(TomlBlock { name: name.to_string(), entries: Vec::new() });
                target = Target::Block(doc.blocks.len() - 1);
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(valid_name(name), "line {}: bad section name {name:?}", lineno + 1);
                target = Target::Section(name.to_string());
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            anyhow::ensure!(valid_name(key), "line {}: bad key {key:?}", lineno + 1);
            let value = parse_value(value.trim())
                .ok_or_else(|| anyhow::anyhow!("line {}: bad value {value:?}", lineno + 1))?;
            match &target {
                Target::Section(section) => {
                    doc.entries.insert((section.clone(), key.to_string()), value);
                }
                Target::Block(i) => {
                    let block = &mut doc.blocks[*i];
                    match block.entries.iter_mut().find(|(k, _)| k == key) {
                        Some(slot) => slot.1 = value,
                        None => block.entries.push((key.to_string(), value)),
                    }
                }
            }
        }
        Ok(doc)
    }

    /// All `[[name]]` blocks with the given name, in document order.
    pub fn blocks(&self, name: &str) -> impl Iterator<Item = &TomlBlock> {
        self.blocks.iter().filter(move |b| b.name == name)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(TomlValue::as_f64)
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key).and_then(TomlValue::as_i64)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        self.get(section, key).and_then(|v| v.as_str().map(str::to_string))
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(TomlValue::as_bool)
    }

    pub fn get_f64_array(&self, section: &str, key: &str) -> Option<Vec<f64>> {
        match self.get(section, key)? {
            TomlValue::Array(items) => items.iter().map(TomlValue::as_f64).collect(),
            _ => None,
        }
    }

    pub fn get_i64_array(&self, section: &str, key: &str) -> Option<Vec<i64>> {
        match self.get(section, key)? {
            TomlValue::Array(items) => items.iter().map(TomlValue::as_i64).collect(),
            _ => None,
        }
    }

    /// Does any key live under `[section]`? An empty section header
    /// leaves no entries, so it is indistinguishable from an absent one —
    /// presence-gated features (e.g. `[overload]`) need at least one key.
    pub fn has_section(&self, section: &str) -> bool {
        self.entries.keys().any(|(s, _)| s == section)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Remove a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        if inner.contains('"') {
            return None; // no escape support; keep the subset strict
        }
        return Some(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Some(TomlValue::Bool(true));
    }
    if s == "false" {
        return Some(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']')?.trim();
        if inner.is_empty() {
            return Some(TomlValue::Array(Vec::new()));
        }
        let items: Option<Vec<TomlValue>> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Some(TomlValue::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        if f.is_finite() {
            return Some(TomlValue::Float(f));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = false\n",
        )
        .unwrap();
        assert_eq!(doc.get_i64("", "a"), Some(1));
        assert_eq!(doc.get_f64("", "b"), Some(2.5));
        assert_eq!(doc.get_str("", "c"), Some("hi".into()));
        assert_eq!(doc.get_bool("", "d"), Some(true));
        assert_eq!(doc.get_bool("", "e"), Some(false));
    }

    #[test]
    fn int_readable_as_f64() {
        let doc = TomlDoc::parse("x = 3\n").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(3.0));
    }

    #[test]
    fn sections_and_subsections() {
        let doc = TomlDoc::parse("[a]\nx = 1\n[a.b]\nx = 2\n").unwrap();
        assert_eq!(doc.get_i64("a", "x"), Some(1));
        assert_eq!(doc.get_i64("a.b", "x"), Some(2));
        assert_eq!(doc.get("b", "x"), None);
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse("xs = [1.5, 2.0]\nis = [1, 2, 3]\nempty = []\n").unwrap();
        assert_eq!(doc.get_f64_array("", "xs"), Some(vec![1.5, 2.0]));
        assert_eq!(doc.get_i64_array("", "is"), Some(vec![1, 2, 3]));
        assert_eq!(doc.get_f64_array("", "empty"), Some(vec![]));
    }

    #[test]
    fn mixed_array_int_as_float_fails_cleanly() {
        let doc = TomlDoc::parse("xs = [1, 2.5]\n").unwrap();
        // i64 view fails (2.5 is not an int) ...
        assert_eq!(doc.get_i64_array("", "xs"), None);
        // ... f64 view accepts both.
        assert_eq!(doc.get_f64_array("", "xs"), Some(vec![1.0, 2.5]));
    }

    #[test]
    fn comments_stripped() {
        let doc = TomlDoc::parse("# full line\nx = 1 # trailing\ns = \"a # not comment\"\n").unwrap();
        assert_eq!(doc.get_i64("", "x"), Some(1));
        assert_eq!(doc.get_str("", "s"), Some("a # not comment".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("x = 1\ny 2\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = TomlDoc::parse("[bad\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn bad_values_rejected() {
        assert!(TomlDoc::parse("x = nope\n").is_err());
        assert!(TomlDoc::parse("x = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("x = [1,\n").is_err());
    }

    #[test]
    fn array_of_tables_blocks() {
        let doc = TomlDoc::parse(
            "[scenario]\nx = 1\n[[query]]\nid = \"a\"\nalpha = 0.8\n[[query]]\nid = \"b\"\ncams = [0, 1]\n[network]\ny = 2\n",
        )
        .unwrap();
        let blocks: Vec<_> = doc.blocks("query").collect();
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].get_str("id"), Some("a".into()));
        assert_eq!(blocks[0].get_f64("alpha"), Some(0.8));
        assert_eq!(blocks[1].get_str("id"), Some("b".into()));
        assert_eq!(blocks[1].get_i64_array("cams"), Some(vec![0, 1]));
        // Blocks don't leak into the flat section view and vice versa.
        assert_eq!(doc.get_i64("scenario", "x"), Some(1));
        assert_eq!(doc.get_i64("network", "y"), Some(2));
        assert_eq!(doc.get("query", "id"), None);
        assert_eq!(doc.blocks("nope").count(), 0);
    }

    #[test]
    fn block_keys_in_order_and_ensure_keys_names_offender() {
        let doc = TomlDoc::parse("[[q]]\nb = 1\na = 2\n").unwrap();
        let block = doc.blocks("q").next().unwrap();
        assert_eq!(block.keys().collect::<Vec<_>>(), vec!["b", "a"]);
        assert!(block.ensure_keys("[[q]]", &["a", "b"]).is_ok());
        let err = block.ensure_keys("[[q]] block 1", &["a"]).unwrap_err().to_string();
        assert!(err.contains("[[q]] block 1"), "{err}");
        assert!(err.contains("\"b\""), "{err}");
        assert!(err.contains("expected one of: a"), "{err}");
    }

    #[test]
    fn bad_block_headers_rejected() {
        assert!(TomlDoc::parse("[[query\n").is_err());
        assert!(TomlDoc::parse("[[query]\n").is_err());
        assert!(TomlDoc::parse("[[ ]]\n").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = TomlDoc::parse("a = -4\nb = -0.5\nc = 1e-3\n").unwrap();
        assert_eq!(doc.get_i64("", "a"), Some(-4));
        assert_eq!(doc.get_f64("", "b"), Some(-0.5));
        assert_eq!(doc.get_f64("", "c"), Some(1e-3));
    }
}
