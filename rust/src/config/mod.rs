//! Configuration system: a TOML-subset parser + typed scenario config.
//!
//! The offline vendor set has no `serde`/`toml`, so a small parser lives
//! here. Supported subset (all this project needs): `[section]` and
//! `[section.sub]` headers, `key = value` with string / float / int /
//! bool / homogeneous inline arrays, `#` comments.

pub mod toml;

use crate::faults::{BurstWindow, CrashWindow, FaultPlan, SlowWindow};
use crate::overload::OverloadConfig;
use crate::types::ClassId;
use std::path::Path;
use toml::TomlDoc;

/// Per-node resources for a scenario.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// Relative speed factor (1.0 = full host speed). The paper produces
    /// heterogeneity by limiting Docker CPU cores; here a 2-core edge is a
    /// speed factor of 0.25 vs the 8-core one at 1.0.
    pub speed: f64,
    /// Number of cameras served by this node.
    pub cameras: u32,
}

/// Scheme selector (Tables II–IV compare the four).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scheme {
    SurveilEdge,
    SurveilEdgeFixed,
    EdgeOnly,
    CloudOnly,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::SurveilEdge => "SurveilEdge",
            Scheme::SurveilEdgeFixed => "SurveilEdge(fixed)",
            Scheme::EdgeOnly => "edge-only",
            Scheme::CloudOnly => "cloud-only",
        }
    }

    pub fn from_name(s: &str) -> Option<Scheme> {
        match s {
            "surveiledge" | "SurveilEdge" => Some(Scheme::SurveilEdge),
            "fixed" | "SurveilEdge(fixed)" | "surveiledge-fixed" => Some(Scheme::SurveilEdgeFixed),
            "edge-only" | "edge" => Some(Scheme::EdgeOnly),
            "cloud-only" | "cloud" => Some(Scheme::CloudOnly),
            _ => None,
        }
    }

    pub fn all() -> [Scheme; 4] {
        [Scheme::SurveilEdgeFixed, Scheme::SurveilEdge, Scheme::EdgeOnly, Scheme::CloudOnly]
    }
}

/// Full scenario configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Edge nodes (index 0 = edge 1). The cloud is implicit.
    pub edges: Vec<NodeSpec>,
    /// Cloud speed factor (its classifier is heavier but its hardware is
    /// faster; both are captured by the service-time calibration).
    pub cloud_speed: f64,
    /// Query object.
    pub query: ClassId,
    /// Query sampling interval `s` in seconds (paper: 1 s).
    pub interval: f64,
    /// Scenario duration (seconds of stream per camera).
    pub duration: f64,
    /// Frame resolution.
    pub frame_h: usize,
    pub frame_w: usize,
    /// Network model: edge->cloud round-trip latency and bandwidth.
    pub rtt: f64,
    pub uplink_mbps: f64,
    /// Threshold controller parameters (γ₁, γ₂).
    pub gamma1: f64,
    pub gamma2: f64,
    /// Random seed for the video substrate.
    pub seed: u64,
    /// Path to the AOT artifact bundle.
    pub artifacts: String,
    /// Fault schedule for chaos scenarios (`[faults]` in TOML). Empty by
    /// default: no injection, zero overhead.
    pub faults: FaultPlan,
    /// Overload control (`[overload]` in TOML). Disabled by default: no
    /// bounded queues, no breaker, no ladder — an exact no-op.
    pub overload: OverloadConfig,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            edges: vec![NodeSpec { speed: 1.0, cameras: 4 }],
            cloud_speed: 1.0,
            query: ClassId::Moped,
            interval: 1.0,
            duration: 120.0,
            frame_h: 96,
            frame_w: 128,
            rtt: 0.06,
            // Shared edge->cloud uplink. Sized so that shipping *every*
            // native-resolution crop (cloud-only) saturates the link —
            // the bandwidth-bound regime the paper's cloud-only baseline
            // exhibits (14.8 s average latency in Table II).
            uplink_mbps: 6.0,
            gamma1: 0.1,
            gamma2: 0.25,
            seed: 7,
            artifacts: "artifacts".into(),
            faults: FaultPlan::none(),
            overload: OverloadConfig::default(),
        }
    }
}

impl Config {
    /// The paper's three prototype settings.
    pub fn single_edge() -> Config {
        Config::default()
    }

    pub fn homogeneous() -> Config {
        Config {
            edges: vec![
                NodeSpec { speed: 1.0, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
            ],
            ..Config::default()
        }
    }

    pub fn heterogeneous() -> Config {
        Config {
            edges: vec![
                // 2 / 4 / 8 logical cores in the paper -> 0.25 / 0.5 / 1.0.
                NodeSpec { speed: 0.25, cameras: 4 },
                NodeSpec { speed: 0.5, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
            ],
            ..Config::default()
        }
    }

    pub fn total_cameras(&self) -> u32 {
        self.edges.iter().map(|e| e.cameras).sum()
    }

    /// Parse from TOML text; missing keys keep defaults.
    pub fn from_toml(text: &str) -> crate::Result<Config> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Config::default();
        if let Some(q) = doc.get_str("query", "object") {
            cfg.query = ClassId::from_name(&q)
                .ok_or_else(|| anyhow::anyhow!("unknown query object {q:?}"))?;
        }
        if let Some(v) = doc.get_f64("query", "interval") {
            cfg.interval = v;
        }
        if let Some(v) = doc.get_f64("scenario", "duration") {
            cfg.duration = v;
        }
        if let Some(v) = doc.get_i64("scenario", "frame_h") {
            cfg.frame_h = v as usize;
        }
        if let Some(v) = doc.get_i64("scenario", "frame_w") {
            cfg.frame_w = v as usize;
        }
        if let Some(v) = doc.get_i64("scenario", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("scenario", "artifacts") {
            cfg.artifacts = v;
        }
        if let Some(v) = doc.get_f64("network", "rtt") {
            cfg.rtt = v;
        }
        if let Some(v) = doc.get_f64("network", "uplink_mbps") {
            cfg.uplink_mbps = v;
        }
        if let Some(v) = doc.get_f64("controller", "gamma1") {
            cfg.gamma1 = v;
        }
        if let Some(v) = doc.get_f64("controller", "gamma2") {
            cfg.gamma2 = v;
        }
        if let Some(v) = doc.get_f64("cloud", "speed") {
            cfg.cloud_speed = v;
        }
        if let Some(speeds) = doc.get_f64_array("edges", "speed") {
            let cams = doc
                .get_i64_array("edges", "cameras")
                .unwrap_or_else(|| vec![4; speeds.len()]);
            anyhow::ensure!(
                cams.len() == speeds.len(),
                "edges.speed and edges.cameras length mismatch"
            );
            cfg.edges = speeds
                .iter()
                .zip(cams.iter())
                .map(|(&s, &c)| NodeSpec { speed: s, cameras: c as u32 })
                .collect();
        }
        if let Some(v) = doc.get_i64("faults", "seed") {
            cfg.faults.seed = v as u64;
        }
        if let Some(v) = doc.get_f64("faults", "drop_p") {
            anyhow::ensure!((0.0..=1.0).contains(&v), "faults.drop_p must be in [0,1]");
            cfg.faults.link.drop_p = v;
        }
        if let Some(v) = doc.get_f64("faults", "delay") {
            cfg.faults.link.delay = v;
        }
        if let Some(v) = doc.get_f64("faults", "jitter") {
            cfg.faults.link.jitter = v;
        }
        // Crash and slow windows use the same parallel-array idiom as
        // [edges]: crash_node[i] is down for [crash_from[i], crash_until[i]).
        if let Some(nodes) = doc.get_i64_array("faults", "crash_node") {
            let from = doc.get_f64_array("faults", "crash_from").unwrap_or_default();
            let until = doc.get_f64_array("faults", "crash_until").unwrap_or_default();
            anyhow::ensure!(
                from.len() == nodes.len() && until.len() == nodes.len(),
                "faults.crash_node/crash_from/crash_until length mismatch"
            );
            cfg.faults.crashes = nodes
                .iter()
                .zip(from.iter().zip(until.iter()))
                .map(|(&n, (&f, &u))| CrashWindow { node: n as u32, from: f, until: u })
                .collect();
        }
        if let Some(nodes) = doc.get_i64_array("faults", "slow_node") {
            let from = doc.get_f64_array("faults", "slow_from").unwrap_or_default();
            let until = doc.get_f64_array("faults", "slow_until").unwrap_or_default();
            let factor = doc.get_f64_array("faults", "slow_factor").unwrap_or_default();
            anyhow::ensure!(
                from.len() == nodes.len() && until.len() == nodes.len() && factor.len() == nodes.len(),
                "faults.slow_node/slow_from/slow_until/slow_factor length mismatch"
            );
            cfg.faults.slow = nodes
                .iter()
                .zip(from.iter().zip(until.iter().zip(factor.iter())))
                .map(|(&n, (&f, (&u, &x)))| SlowWindow { node: n as u32, from: f, until: u, factor: x })
                .collect();
        }
        // Overload control: the *presence* of `[overload]` enables the
        // subsystem; without the block every consumer treats it as absent
        // and existing runs stay byte-identical.
        if doc.has_section("overload") {
            cfg.overload.enabled = true;
            if let Some(v) = doc.get_i64("overload", "node_queue_cap") {
                anyhow::ensure!(v >= 0, "overload.node_queue_cap must be >= 0");
                cfg.overload.node_queue_cap = v as usize;
            }
            if let Some(v) = doc.get_i64("overload", "uplink_queue_cap") {
                anyhow::ensure!(v >= 0, "overload.uplink_queue_cap must be >= 0");
                cfg.overload.uplink_queue_cap = v as usize;
            }
            if let Some(v) = doc.get_i64("overload", "retry_budget") {
                anyhow::ensure!(v >= 0, "overload.retry_budget must be >= 0");
                cfg.overload.retry_budget = v as u32;
            }
            if let Some(v) = doc.get_i64("overload", "trip_after") {
                anyhow::ensure!(v >= 1, "overload.trip_after must be >= 1");
                cfg.overload.breaker.trip_after = v as u32;
            }
            if let Some(v) = doc.get_f64("overload", "cooldown") {
                cfg.overload.breaker.cooldown = v;
            }
            if let Some(v) = doc.get_f64("overload", "cooldown_max") {
                cfg.overload.breaker.cooldown_max = v;
            }
            if let Some(v) = doc.get_i64("overload", "probe_successes") {
                anyhow::ensure!(v >= 1, "overload.probe_successes must be >= 1");
                cfg.overload.breaker.probe_successes = v as u32;
            }
            if let Some(up) = doc.get_f64_array("overload", "ladder_up") {
                anyhow::ensure!(up.len() == 3, "overload.ladder_up needs exactly 3 thresholds");
                cfg.overload.ladder.up = [up[0], up[1], up[2]];
            }
            if let Some(v) = doc.get_f64("overload", "ladder_slack") {
                cfg.overload.ladder.slack = v;
            }
            if let Some(v) = doc.get_f64("overload", "ladder_sustain") {
                cfg.overload.ladder.sustain = v;
            }
            if let Some(v) = doc.get_f64("overload", "subsample_drop") {
                cfg.overload.subsample_drop = v;
            }
            // Burst windows use the same parallel-array idiom as crash
            // windows: every detection in [burst_from[i], burst_until[i])
            // yields burst_factor[i] tasks.
            if let Some(from) = doc.get_f64_array("overload", "burst_from") {
                let until = doc.get_f64_array("overload", "burst_until").unwrap_or_default();
                let factor = doc.get_i64_array("overload", "burst_factor").unwrap_or_default();
                anyhow::ensure!(
                    until.len() == from.len() && factor.len() == from.len(),
                    "overload.burst_from/burst_until/burst_factor length mismatch"
                );
                cfg.overload.bursts = from
                    .iter()
                    .zip(until.iter().zip(factor.iter()))
                    .map(|(&f, (&u, &x))| BurstWindow { from: f, until: u, factor: x as u32 })
                    .collect();
            }
            cfg.overload.validate()?;
        }
        anyhow::ensure!(!cfg.edges.is_empty(), "at least one edge required");
        anyhow::ensure!(cfg.interval > 0.0, "interval must be positive");
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> crate::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
        assert!(Scheme::from_name("bogus").is_none());
    }

    #[test]
    fn default_config_sane() {
        let c = Config::default();
        assert_eq!(c.total_cameras(), 4);
        assert_eq!(c.query, ClassId::Moped);
    }

    #[test]
    fn paper_presets() {
        assert_eq!(Config::single_edge().edges.len(), 1);
        assert_eq!(Config::homogeneous().edges.len(), 3);
        let het = Config::heterogeneous();
        assert_eq!(het.edges.len(), 3);
        assert!(het.edges[0].speed < het.edges[2].speed);
        assert_eq!(het.total_cameras(), 12);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# scenario file
[query]
object = "bicycle"
interval = 0.5

[scenario]
duration = 60.0
frame_h = 48
frame_w = 64
seed = 99
artifacts = "custom/artifacts"

[network]
rtt = 0.1
uplink_mbps = 5.0

[controller]
gamma1 = 0.2
gamma2 = 0.3

[cloud]
speed = 2.0

[edges]
speed = [0.25, 1.0]
cameras = [3, 5]
"#;
        let c = Config::from_toml(text).unwrap();
        assert_eq!(c.query, ClassId::Bicycle);
        assert_eq!(c.interval, 0.5);
        assert_eq!(c.duration, 60.0);
        assert_eq!((c.frame_h, c.frame_w), (48, 64));
        assert_eq!(c.seed, 99);
        assert_eq!(c.artifacts, "custom/artifacts");
        assert_eq!(c.rtt, 0.1);
        assert_eq!(c.uplink_mbps, 5.0);
        assert_eq!((c.gamma1, c.gamma2), (0.2, 0.3));
        assert_eq!(c.cloud_speed, 2.0);
        assert_eq!(c.edges.len(), 2);
        assert_eq!(c.edges[0].speed, 0.25);
        assert_eq!(c.edges[1].cameras, 5);
    }

    #[test]
    fn parse_partial_keeps_defaults() {
        let c = Config::from_toml("[query]\nobject = \"person\"\n").unwrap();
        assert_eq!(c.query, ClassId::Person);
        assert_eq!(c.interval, 1.0);
        assert_eq!(c.edges.len(), 1);
    }

    #[test]
    fn parse_rejects_bad_object() {
        assert!(Config::from_toml("[query]\nobject = \"dragon\"\n").is_err());
    }

    #[test]
    fn parse_rejects_mismatched_edge_arrays() {
        let text = "[edges]\nspeed = [1.0, 0.5]\ncameras = [4]\n";
        assert!(Config::from_toml(text).is_err());
    }

    #[test]
    fn parse_fault_plan() {
        let text = r#"
[faults]
seed = 42
drop_p = 0.05
delay = 0.02
jitter = 0.01
crash_node = [1, 2]
crash_from = [10.0, 50.0]
crash_until = [40.0, 55.0]
slow_node = [3]
slow_from = [0.0]
slow_until = [30.0]
slow_factor = [2.5]
"#;
        let c = Config::from_toml(text).unwrap();
        assert_eq!(c.faults.seed, 42);
        assert_eq!(c.faults.link.drop_p, 0.05);
        assert_eq!(c.faults.link.delay, 0.02);
        assert_eq!(c.faults.link.jitter, 0.01);
        assert_eq!(c.faults.crashes.len(), 2);
        assert!(c.faults.is_down(1, 20.0));
        assert!(!c.faults.is_down(1, 45.0));
        assert!(c.faults.is_down(2, 52.0));
        assert_eq!(c.faults.slowdown(3, 10.0), 2.5);
        assert!(!c.faults.is_empty());
    }

    #[test]
    fn parse_overload_block() {
        let text = r#"
[overload]
node_queue_cap = 6
uplink_queue_cap = 4
retry_budget = 2
trip_after = 4
cooldown = 1.5
cooldown_max = 12.0
probe_successes = 3
ladder_up = [0.4, 0.6, 0.8]
ladder_slack = 0.2
ladder_sustain = 3.0
subsample_drop = 0.25
burst_from = [20.0, 70.0]
burst_until = [40.0, 80.0]
burst_factor = [3, 2]
"#;
        let c = Config::from_toml(text).unwrap();
        let o = &c.overload;
        assert!(o.enabled, "presence of [overload] enables the subsystem");
        assert_eq!(o.node_queue_cap, 6);
        assert_eq!(o.uplink_queue_cap, 4);
        assert_eq!(o.retry_budget, 2);
        assert_eq!(o.breaker.trip_after, 4);
        assert_eq!(o.breaker.cooldown, 1.5);
        assert_eq!(o.breaker.cooldown_max, 12.0);
        assert_eq!(o.breaker.probe_successes, 3);
        assert_eq!(o.ladder.up, [0.4, 0.6, 0.8]);
        assert_eq!(o.ladder.slack, 0.2);
        assert_eq!(o.ladder.sustain, 3.0);
        assert_eq!(o.subsample_drop, 0.25);
        assert_eq!(o.bursts.len(), 2);
        assert_eq!(o.burst_factor(25.0), 3);
        assert_eq!(o.burst_factor(50.0), 1);
    }

    #[test]
    fn parse_overload_absent_stays_disabled() {
        let c = Config::from_toml("[query]\nobject = \"person\"\n").unwrap();
        assert!(!c.overload.enabled, "no [overload] block = subsystem inert");
    }

    #[test]
    fn parse_overload_validates() {
        assert!(Config::from_toml("[overload]\nsubsample_drop = 1.5\n").is_err());
        assert!(Config::from_toml("[overload]\nladder_up = [0.9, 0.5, 0.7]\n").is_err());
        assert!(Config::from_toml("[overload]\nladder_up = [0.5, 0.7]\n").is_err());
        assert!(Config::from_toml("[overload]\ntrip_after = 0\n").is_err());
        let mismatched = "[overload]\nburst_from = [1.0]\nburst_until = [5.0, 9.0]\nburst_factor = [2]\n";
        assert!(Config::from_toml(mismatched).is_err());
        let inverted = "[overload]\nburst_from = [10.0]\nburst_until = [5.0]\nburst_factor = [2]\n";
        assert!(Config::from_toml(inverted).is_err());
    }

    #[test]
    fn parse_faults_defaults_empty_and_validates() {
        let c = Config::from_toml("[query]\nobject = \"person\"\n").unwrap();
        assert!(c.faults.is_empty(), "no [faults] section = empty plan");
        assert!(Config::from_toml("[faults]\ndrop_p = 1.5\n").is_err());
        let mismatched = "[faults]\ncrash_node = [1]\ncrash_from = [1.0, 2.0]\ncrash_until = [5.0]\n";
        assert!(Config::from_toml(mismatched).is_err());
    }
}
