//! Configuration system: a TOML-subset parser + typed scenario config.
//!
//! The offline vendor set has no `serde`/`toml`, so a small parser lives
//! here. Supported subset (all this project needs): `[section]` and
//! `[section.sub]` headers, `key = value` with string / float / int /
//! bool / homogeneous inline arrays, `#` comments.

pub mod toml;

use crate::types::ClassId;
use std::path::Path;
use toml::TomlDoc;

/// Per-node resources for a scenario.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// Relative speed factor (1.0 = full host speed). The paper produces
    /// heterogeneity by limiting Docker CPU cores; here a 2-core edge is a
    /// speed factor of 0.25 vs the 8-core one at 1.0.
    pub speed: f64,
    /// Number of cameras served by this node.
    pub cameras: u32,
}

/// Scheme selector (Tables II–IV compare the four).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scheme {
    SurveilEdge,
    SurveilEdgeFixed,
    EdgeOnly,
    CloudOnly,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::SurveilEdge => "SurveilEdge",
            Scheme::SurveilEdgeFixed => "SurveilEdge(fixed)",
            Scheme::EdgeOnly => "edge-only",
            Scheme::CloudOnly => "cloud-only",
        }
    }

    pub fn from_name(s: &str) -> Option<Scheme> {
        match s {
            "surveiledge" | "SurveilEdge" => Some(Scheme::SurveilEdge),
            "fixed" | "SurveilEdge(fixed)" | "surveiledge-fixed" => Some(Scheme::SurveilEdgeFixed),
            "edge-only" | "edge" => Some(Scheme::EdgeOnly),
            "cloud-only" | "cloud" => Some(Scheme::CloudOnly),
            _ => None,
        }
    }

    pub fn all() -> [Scheme; 4] {
        [Scheme::SurveilEdgeFixed, Scheme::SurveilEdge, Scheme::EdgeOnly, Scheme::CloudOnly]
    }
}

/// Full scenario configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Edge nodes (index 0 = edge 1). The cloud is implicit.
    pub edges: Vec<NodeSpec>,
    /// Cloud speed factor (its classifier is heavier but its hardware is
    /// faster; both are captured by the service-time calibration).
    pub cloud_speed: f64,
    /// Query object.
    pub query: ClassId,
    /// Query sampling interval `s` in seconds (paper: 1 s).
    pub interval: f64,
    /// Scenario duration (seconds of stream per camera).
    pub duration: f64,
    /// Frame resolution.
    pub frame_h: usize,
    pub frame_w: usize,
    /// Network model: edge->cloud round-trip latency and bandwidth.
    pub rtt: f64,
    pub uplink_mbps: f64,
    /// Threshold controller parameters (γ₁, γ₂).
    pub gamma1: f64,
    pub gamma2: f64,
    /// Random seed for the video substrate.
    pub seed: u64,
    /// Path to the AOT artifact bundle.
    pub artifacts: String,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            edges: vec![NodeSpec { speed: 1.0, cameras: 4 }],
            cloud_speed: 1.0,
            query: ClassId::Moped,
            interval: 1.0,
            duration: 120.0,
            frame_h: 96,
            frame_w: 128,
            rtt: 0.06,
            // Shared edge->cloud uplink. Sized so that shipping *every*
            // native-resolution crop (cloud-only) saturates the link —
            // the bandwidth-bound regime the paper's cloud-only baseline
            // exhibits (14.8 s average latency in Table II).
            uplink_mbps: 6.0,
            gamma1: 0.1,
            gamma2: 0.25,
            seed: 7,
            artifacts: "artifacts".into(),
        }
    }
}

impl Config {
    /// The paper's three prototype settings.
    pub fn single_edge() -> Config {
        Config::default()
    }

    pub fn homogeneous() -> Config {
        Config {
            edges: vec![
                NodeSpec { speed: 1.0, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
            ],
            ..Config::default()
        }
    }

    pub fn heterogeneous() -> Config {
        Config {
            edges: vec![
                // 2 / 4 / 8 logical cores in the paper -> 0.25 / 0.5 / 1.0.
                NodeSpec { speed: 0.25, cameras: 4 },
                NodeSpec { speed: 0.5, cameras: 4 },
                NodeSpec { speed: 1.0, cameras: 4 },
            ],
            ..Config::default()
        }
    }

    pub fn total_cameras(&self) -> u32 {
        self.edges.iter().map(|e| e.cameras).sum()
    }

    /// Parse from TOML text; missing keys keep defaults.
    pub fn from_toml(text: &str) -> crate::Result<Config> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Config::default();
        if let Some(q) = doc.get_str("query", "object") {
            cfg.query = ClassId::from_name(&q)
                .ok_or_else(|| anyhow::anyhow!("unknown query object {q:?}"))?;
        }
        if let Some(v) = doc.get_f64("query", "interval") {
            cfg.interval = v;
        }
        if let Some(v) = doc.get_f64("scenario", "duration") {
            cfg.duration = v;
        }
        if let Some(v) = doc.get_i64("scenario", "frame_h") {
            cfg.frame_h = v as usize;
        }
        if let Some(v) = doc.get_i64("scenario", "frame_w") {
            cfg.frame_w = v as usize;
        }
        if let Some(v) = doc.get_i64("scenario", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("scenario", "artifacts") {
            cfg.artifacts = v;
        }
        if let Some(v) = doc.get_f64("network", "rtt") {
            cfg.rtt = v;
        }
        if let Some(v) = doc.get_f64("network", "uplink_mbps") {
            cfg.uplink_mbps = v;
        }
        if let Some(v) = doc.get_f64("controller", "gamma1") {
            cfg.gamma1 = v;
        }
        if let Some(v) = doc.get_f64("controller", "gamma2") {
            cfg.gamma2 = v;
        }
        if let Some(v) = doc.get_f64("cloud", "speed") {
            cfg.cloud_speed = v;
        }
        if let Some(speeds) = doc.get_f64_array("edges", "speed") {
            let cams = doc
                .get_i64_array("edges", "cameras")
                .unwrap_or_else(|| vec![4; speeds.len()]);
            anyhow::ensure!(
                cams.len() == speeds.len(),
                "edges.speed and edges.cameras length mismatch"
            );
            cfg.edges = speeds
                .iter()
                .zip(cams.iter())
                .map(|(&s, &c)| NodeSpec { speed: s, cameras: c as u32 })
                .collect();
        }
        anyhow::ensure!(!cfg.edges.is_empty(), "at least one edge required");
        anyhow::ensure!(cfg.interval > 0.0, "interval must be positive");
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> crate::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
        assert!(Scheme::from_name("bogus").is_none());
    }

    #[test]
    fn default_config_sane() {
        let c = Config::default();
        assert_eq!(c.total_cameras(), 4);
        assert_eq!(c.query, ClassId::Moped);
    }

    #[test]
    fn paper_presets() {
        assert_eq!(Config::single_edge().edges.len(), 1);
        assert_eq!(Config::homogeneous().edges.len(), 3);
        let het = Config::heterogeneous();
        assert_eq!(het.edges.len(), 3);
        assert!(het.edges[0].speed < het.edges[2].speed);
        assert_eq!(het.total_cameras(), 12);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# scenario file
[query]
object = "bicycle"
interval = 0.5

[scenario]
duration = 60.0
frame_h = 48
frame_w = 64
seed = 99
artifacts = "custom/artifacts"

[network]
rtt = 0.1
uplink_mbps = 5.0

[controller]
gamma1 = 0.2
gamma2 = 0.3

[cloud]
speed = 2.0

[edges]
speed = [0.25, 1.0]
cameras = [3, 5]
"#;
        let c = Config::from_toml(text).unwrap();
        assert_eq!(c.query, ClassId::Bicycle);
        assert_eq!(c.interval, 0.5);
        assert_eq!(c.duration, 60.0);
        assert_eq!((c.frame_h, c.frame_w), (48, 64));
        assert_eq!(c.seed, 99);
        assert_eq!(c.artifacts, "custom/artifacts");
        assert_eq!(c.rtt, 0.1);
        assert_eq!(c.uplink_mbps, 5.0);
        assert_eq!((c.gamma1, c.gamma2), (0.2, 0.3));
        assert_eq!(c.cloud_speed, 2.0);
        assert_eq!(c.edges.len(), 2);
        assert_eq!(c.edges[0].speed, 0.25);
        assert_eq!(c.edges[1].cameras, 5);
    }

    #[test]
    fn parse_partial_keeps_defaults() {
        let c = Config::from_toml("[query]\nobject = \"person\"\n").unwrap();
        assert_eq!(c.query, ClassId::Person);
        assert_eq!(c.interval, 1.0);
        assert_eq!(c.edges.len(), 1);
    }

    #[test]
    fn parse_rejects_bad_object() {
        assert!(Config::from_toml("[query]\nobject = \"dragon\"\n").is_err());
    }

    #[test]
    fn parse_rejects_mismatched_edge_arrays() {
        let text = "[edges]\nspeed = [1.0, 0.5]\ncameras = [4]\n";
        assert!(Config::from_toml(text).is_err());
    }
}
