//! SurveilEdge: real-time cloud–edge surveillance video query.
//!
//! Reproduction of *SurveilEdge: Real-time Video Query based on Collaborative
//! Cloud-Edge Deep Learning* (Wang, Yang, Zhao — INFOCOM 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: frame-difference
//!   detection, the task allocator (`argmin Qᵢ·tᵢ`), α/β threshold
//!   adaptation, latency estimation (eq. 17 + 3-parameter lognormal MLE),
//!   camera clustering, the MQTT-like bus, the parameter DB, edge/cloud node
//!   event loops, and the query coordinator.
//! * **L2/L1 (build-time Python)** — EdgeCNN / CloudCNN / train-step /
//!   frame-difference graphs, lowered once to HLO text (`artifacts/`).
//! * **Runtime bridge** — [`runtime`] loads the HLO artifacts via the PJRT
//!   CPU client (`xla` crate) and executes them from the request path.
//!   Python is never on the request path.
//!
//! See `DESIGN.md` for the module inventory and `EXPERIMENTS.md` for the
//! reproduced tables/figures.

pub mod benchkit;
pub mod bus;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod detect;
pub mod estimator;
pub mod faults;
pub mod harness;
pub mod metrics;
pub mod nodes;
pub mod obs;
pub mod overload;
pub mod paramdb;
pub mod query;
pub mod runtime;
pub mod sched;
pub mod simclock;
pub mod testkit;
pub mod trace;
pub mod types;
pub mod video;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
