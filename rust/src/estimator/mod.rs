//! Inference-latency estimation (paper §IV-D-3).
//!
//! Two estimators, used together exactly as the paper prescribes:
//!
//! * [`AdaptiveMean`] — the fast path, eq. 17: a self-weighted mean of the
//!   stored value and the newest feedback, which automatically discounts
//!   outliers. Updated on every classification feedback.
//! * [`Lognormal3`] — the slow path: maximum-likelihood fit of a
//!   three-parameter (shifted) lognormal over a window of recent latencies
//!   (eqs. 10–16), used for long-period prediction. The location parameter
//!   γ models the physical minimum latency. Prediction blends E(X) with the
//!   median (γ + e^μ) to damp outlier swings, as §IV-D-3 describes.

use std::collections::VecDeque;

/// Eq. 17: t ← (t_old² + t_new²)/(t_old + t_new)² · t_old
///            + 2·t_old·t_new/(t_old + t_new)² · t_new.
///
/// Weights sum to 1; an extreme `t_new` (or a stale extreme `t_old`)
/// receives a reduced weight, bounding swings.
pub fn adaptive_mean_update(t_old: f64, t_new: f64) -> f64 {
    let s = t_old + t_new;
    if s <= 0.0 {
        return t_new.max(0.0);
    }
    let s2 = s * s;
    let w_old = (t_old * t_old + t_new * t_new) / s2;
    let w_new = (2.0 * t_old * t_new) / s2;
    w_old * t_old + w_new * t_new
}

/// Stateful eq.-17 estimator.
#[derive(Clone, Debug)]
pub struct AdaptiveMean {
    value: f64,
}

impl AdaptiveMean {
    /// Start from an empirical initial value (paper: "initialize the value
    /// of latency with an empirical value").
    pub fn new(initial: f64) -> AdaptiveMean {
        AdaptiveMean { value: initial }
    }

    pub fn observe(&mut self, t_new: f64) {
        self.value = adaptive_mean_update(self.value, t_new);
    }

    pub fn value(&self) -> f64 {
        self.value
    }
}

/// Fitted three-parameter lognormal.
#[derive(Clone, Copy, Debug)]
pub struct Lognormal3Fit {
    pub mu: f64,
    pub sigma: f64,
    pub gamma: f64,
}

impl Lognormal3Fit {
    /// E(X) = γ + exp(μ + σ²/2).
    pub fn mean(&self) -> f64 {
        self.gamma + (self.mu + self.sigma * self.sigma / 2.0).exp()
    }

    /// Median(X) = γ + e^μ.
    pub fn median(&self) -> f64 {
        self.gamma + self.mu.exp()
    }

    /// The paper's long-period predictor: a weighted blend of mean and
    /// median (outlier-damped). `w` is the weight on the mean.
    pub fn predict(&self, w: f64) -> f64 {
        let w = w.clamp(0.0, 1.0);
        w * self.mean() + (1.0 - w) * self.median()
    }

    /// Density at `x` (diagnostic / tests).
    pub fn pdf(&self, x: f64) -> f64 {
        if x <= self.gamma {
            return 0.0;
        }
        let z = ((x - self.gamma).ln() - self.mu) / self.sigma;
        (-(z * z) / 2.0).exp()
            / ((x - self.gamma) * self.sigma * (2.0 * std::f64::consts::PI).sqrt())
    }
}

/// Given γ, the profile-likelihood estimates of μ and σ² (eqs. 14–15).
fn mu_sigma_given_gamma(xs: &[f64], gamma: f64) -> (f64, f64) {
    let n = xs.len() as f64;
    let mu = xs.iter().map(|&x| (x - gamma).ln()).sum::<f64>() / n;
    let s2 = xs.iter().map(|&x| ((x - gamma).ln() - mu).powi(2)).sum::<f64>() / n;
    (mu, s2)
}

/// Eq. 16 residual: the γ score equation after substituting eqs. 14–15.
/// The MLE γ̂ is a root of this function on (−∞, min(xs)).
fn gamma_equation(xs: &[f64], gamma: f64) -> f64 {
    let n = xs.len() as f64;
    let inv: f64 = xs.iter().map(|&x| 1.0 / (x - gamma)).sum();
    let lns: f64 = xs.iter().map(|&x| (x - gamma).ln()).sum();
    let ln2s: f64 = xs.iter().map(|&x| (x - gamma).ln().powi(2)).sum();
    let lnoverx: f64 = xs.iter().map(|&x| (x - gamma).ln() / (x - gamma)).sum();
    inv * (lns - ln2s + lns * lns / n) - n * lnoverx
}

/// MLE fit of the three-parameter lognormal by solving eq. 16 for γ with
/// bisection over (lo, min(xs)), then eqs. 14–15 for μ, σ.
///
/// Returns `None` when `xs` is too small or degenerate (constant sample).
pub fn fit_lognormal3(xs: &[f64]) -> Option<Lognormal3Fit> {
    if xs.len() < 8 {
        return None;
    }
    let xmin = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let xmax = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(xmin.is_finite() && xmax.is_finite()) || xmax - xmin < 1e-12 || xmin <= 0.0 {
        return None;
    }
    // Bracket: γ ∈ [xmin - span*8, xmin - eps]. The score equation is
    // continuous there; scan for a sign change, then bisect.
    let span = (xmax - xmin).max(1e-9);
    let eps = 1e-9 * span.max(xmin);
    let hi = xmin - eps;
    let lo = (xmin - 8.0 * span).min(hi - span);
    // §Perf: 24 scan steps + 48 bisection iterations with the bracket-end
    // value cached (the equation is O(n) per evaluation; the original
    // 64-step scan + 80 double-eval bisections dominated the estimator's
    // p99 — see EXPERIMENTS.md §Perf).
    let steps = 24;
    let mut prev_g = lo;
    let mut prev_f = gamma_equation(xs, prev_g);
    let mut bracket = None;
    for i in 1..=steps {
        let g = lo + (hi - lo) * i as f64 / steps as f64;
        let f = gamma_equation(xs, g);
        if f == 0.0 {
            bracket = Some((g, g, f));
            break;
        }
        if prev_f.is_finite() && f.is_finite() && prev_f * f < 0.0 {
            bracket = Some((prev_g, g, prev_f));
            break;
        }
        prev_g = g;
        prev_f = f;
    }
    let (mut a, mut b, mut fa) = match bracket {
        Some(ab) => ab,
        // No root in range: fall back to γ slightly below the sample
        // minimum (common when the true γ ≈ xmin, e.g. heavy left pile-up).
        None => {
            let gamma = xmin - 0.05 * span;
            let (mu, s2) = mu_sigma_given_gamma(xs, gamma);
            return Some(Lognormal3Fit { mu, sigma: s2.sqrt().max(1e-9), gamma });
        }
    };
    for _ in 0..48 {
        let mid = 0.5 * (a + b);
        let fm = gamma_equation(xs, mid);
        if fm == 0.0 || (b - a) < 1e-12 {
            a = mid;
            b = mid;
            break;
        }
        if fa * fm < 0.0 {
            b = mid;
        } else {
            a = mid;
            fa = fm;
        }
    }
    let gamma = 0.5 * (a + b);
    let (mu, s2) = mu_sigma_given_gamma(xs, gamma);
    Some(Lognormal3Fit { mu, sigma: s2.sqrt().max(1e-9), gamma })
}

/// Long-period latency estimator: keeps a sliding window of observations
/// and refits the 3-parameter lognormal every `refit_every` samples.
#[derive(Clone, Debug)]
pub struct Lognormal3 {
    window: VecDeque<f64>,
    capacity: usize,
    refit_every: usize,
    since_fit: usize,
    fit: Option<Lognormal3Fit>,
    /// Blend weight on E(X) vs median in `predict` (paper: weighted
    /// arithmetic mean of the two).
    pub mean_weight: f64,
}

impl Lognormal3 {
    pub fn new(capacity: usize, refit_every: usize) -> Lognormal3 {
        Lognormal3 {
            window: VecDeque::with_capacity(capacity),
            capacity: capacity.max(8),
            refit_every: refit_every.max(1),
            since_fit: 0,
            fit: None,
            mean_weight: 0.5,
        }
    }

    pub fn observe(&mut self, x: f64) {
        if !(x.is_finite() && x > 0.0) {
            return;
        }
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(x);
        self.since_fit += 1;
        if self.since_fit >= self.refit_every && self.window.len() >= 8 {
            let xs: Vec<f64> = self.window.iter().cloned().collect();
            if let Some(f) = fit_lognormal3(&xs) {
                self.fit = Some(f);
            }
            self.since_fit = 0;
        }
    }

    pub fn fit(&self) -> Option<Lognormal3Fit> {
        self.fit
    }

    /// Long-period prediction; `None` until enough data has arrived.
    pub fn predict(&self) -> Option<f64> {
        self.fit.map(|f| f.predict(self.mean_weight))
    }
}

/// The combined estimator the nodes use: eq. 17 on every feedback (fast,
/// frequent) plus the lognormal refit as the long-period corrector — the
/// paper notes the lognormal "can compensate for the lower reliability of
/// this simple method in longer periods".
#[derive(Clone, Debug)]
pub struct LatencyEstimator {
    fast: AdaptiveMean,
    slow: Lognormal3,
    /// Weight on the slow (lognormal) prediction when available.
    pub slow_weight: f64,
}

impl LatencyEstimator {
    pub fn new(initial: f64) -> LatencyEstimator {
        LatencyEstimator {
            fast: AdaptiveMean::new(initial),
            slow: Lognormal3::new(256, 32),
            slow_weight: 0.3,
        }
    }

    pub fn observe(&mut self, t: f64) {
        self.fast.observe(t);
        self.slow.observe(t);
    }

    /// Current best estimate of per-task inference latency.
    pub fn estimate(&self) -> f64 {
        match self.slow.predict() {
            Some(lp) => (1.0 - self.slow_weight) * self.fast.value() + self.slow_weight * lp,
            None => self.fast.value(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn eq17_fixed_point() {
        // If feedback equals the stored value, nothing changes.
        let t = adaptive_mean_update(0.8, 0.8);
        assert!((t - 0.8).abs() < 1e-12);
    }

    #[test]
    fn eq17_weights_sum_to_one() {
        check("eq17_weights", |rng, _| {
            let a = rng.range_f64(1e-3, 10.0);
            let b = rng.range_f64(1e-3, 10.0);
            let s2 = (a + b) * (a + b);
            let w_old = (a * a + b * b) / s2;
            let w_new = 2.0 * a * b / s2;
            assert!((w_old + w_new - 1.0).abs() < 1e-12);
            // Result lies between the two inputs.
            let t = adaptive_mean_update(a, b);
            assert!(t >= a.min(b) - 1e-12 && t <= a.max(b) + 1e-12);
        });
    }

    #[test]
    fn eq17_damps_outliers_vs_plain_mean() {
        // A 100x outlier must move the estimate less than the plain
        // arithmetic mean would.
        let t_old = 0.1f64;
        let spike = 10.0f64;
        let updated = adaptive_mean_update(t_old, spike);
        let plain = 0.5 * (t_old + spike);
        assert!(updated < plain, "eq17 {updated} >= mean {plain}");
        // The outlier's effective weight is 2ab/(a+b)^2 ≈ 0.0196.
        assert!(updated < 0.5, "outlier influence too large: {updated}");
    }

    #[test]
    fn eq17_converges_to_stable_feedback() {
        let mut est = AdaptiveMean::new(5.0);
        for _ in 0..200 {
            est.observe(0.25);
        }
        assert!((est.value() - 0.25).abs() < 0.01, "value {}", est.value());
    }

    #[test]
    fn lognormal_fit_recovers_parameters() {
        let (mu, sigma, gamma) = (-1.2, 0.5, 0.3);
        let mut rng = Rng::new(42);
        let xs: Vec<f64> = (0..4000).map(|_| rng.lognormal3(mu, sigma, gamma)).collect();
        let fit = fit_lognormal3(&xs).expect("fit");
        assert!((fit.gamma - gamma).abs() < 0.1, "gamma {} vs {gamma}", fit.gamma);
        assert!((fit.mu - mu).abs() < 0.25, "mu {} vs {mu}", fit.mu);
        assert!((fit.sigma - sigma).abs() < 0.15, "sigma {} vs {sigma}", fit.sigma);
        // E(X) close to the sample mean.
        let sample_mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((fit.mean() - sample_mean).abs() / sample_mean < 0.05);
    }

    #[test]
    fn lognormal_fit_rejects_degenerate() {
        assert!(fit_lognormal3(&[1.0; 20]).is_none());
        assert!(fit_lognormal3(&[1.0, 2.0, 3.0]).is_none()); // too few
    }

    #[test]
    fn lognormal_gamma_below_min() {
        check("gamma_below_min", |rng, _| {
            let gamma = rng.range_f64(0.0, 1.0);
            let mu = rng.range_f64(-2.0, 0.5);
            let sigma = rng.range_f64(0.2, 1.0);
            let xs: Vec<f64> = (0..200).map(|_| rng.lognormal3(mu, sigma, gamma)).collect();
            if let Some(fit) = fit_lognormal3(&xs) {
                let xmin = xs.iter().cloned().fold(f64::INFINITY, f64::min);
                assert!(fit.gamma < xmin, "gamma {} >= xmin {xmin}", fit.gamma);
                assert!(fit.sigma > 0.0);
                assert!(fit.mean() >= fit.median(), "lognormal mean < median");
            }
        });
    }

    #[test]
    fn pdf_zero_below_gamma_positive_above() {
        let fit = Lognormal3Fit { mu: 0.0, sigma: 1.0, gamma: 1.0 };
        assert_eq!(fit.pdf(0.5), 0.0);
        assert_eq!(fit.pdf(1.0), 0.0);
        assert!(fit.pdf(2.0) > 0.0);
    }

    #[test]
    fn sliding_estimator_tracks_distribution() {
        let mut est = Lognormal3::new(256, 16);
        let mut rng = Rng::new(3);
        for _ in 0..512 {
            est.observe(rng.lognormal3(-1.0, 0.4, 0.2));
        }
        let pred = est.predict().expect("prediction after 512 samples");
        // True median = 0.2 + e^-1 ≈ 0.568, mean ≈ 0.2+e^{-1+0.08}≈0.599.
        assert!((0.4..0.8).contains(&pred), "pred {pred}");
    }

    #[test]
    fn combined_estimator_blends() {
        let mut est = LatencyEstimator::new(1.0);
        let mut rng = Rng::new(4);
        for _ in 0..600 {
            est.observe(rng.lognormal3(-1.6, 0.3, 0.05));
        }
        let e = est.estimate();
        // True mean ≈ 0.05 + e^{-1.6+0.045} ≈ 0.26.
        assert!((0.1..0.5).contains(&e), "estimate {e}");
    }

    #[test]
    fn combined_estimator_resists_single_spike() {
        let mut est = LatencyEstimator::new(0.2);
        for _ in 0..100 {
            est.observe(0.2);
        }
        let before = est.estimate();
        est.observe(50.0); // one pathological outlier
        let after = est.estimate();
        // A plain running mean over the window would jump by ~0.49; a plain
        // 50/50 mean by ~24.9. Eq. 17 + the lognormal blend must damp the
        // spike well below the naive two-point mean...
        let naive_jump = 0.5 * (before + 50.0) - before;
        assert!(after - before < 0.2 * naive_jump, "spike {before} -> {after}");
        // ...and recover quickly.
        for _ in 0..20 {
            est.observe(0.2);
        }
        assert!((est.estimate() - before).abs() < 0.1, "no recovery: {}", est.estimate());
    }
}
