//! Analytic sprite renderer — the Rust mirror of `python/compile/data.py`.
//!
//! The serving-side object distribution must equal the distribution the
//! CNNs were trained on, so this file implements the *same specification*:
//! per-pixel analytic masks in canonical [-1,1]² coordinates, f32 math,
//! `lowbias32`-hashed per-pixel noise, no anti-aliasing. Golden tests below
//! compare pixels against `artifacts/golden_sprites.bin` produced by the
//! Python side.

use crate::types::{ClassId, Image};

/// Dark wheel/tyre colour (shared constant with data.py::WHEEL).
pub const WHEEL: [f32; 3] = [0.13, 0.13, 0.15];

/// Fully explicit, RNG-free description of one rendered object.
#[derive(Clone, Debug)]
pub struct SpriteParams {
    pub cls: ClassId,
    pub size: usize,
    pub base: [f32; 3],
    pub accent: [f32; 3],
    pub bg: [f32; 3],
    pub rot: f32,
    pub jx: f32,
    pub jy: f32,
    pub noise: f32,
    pub seed: u32,
}

/// lowbias32-style integer hash; same constants as data.py::_hash32.
#[inline]
pub fn hash32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Uniform noise in [-1, 1] for pixel (x, y) under `seed`.
#[inline]
pub fn pixel_noise(x: u32, y: u32, seed: u32) -> f32 {
    let h = hash32(
        x.wrapping_mul(73_856_093) ^ y.wrapping_mul(19_349_663) ^ seed.wrapping_mul(83_492_791),
    );
    (h as f32 / 4_294_967_295.0) * 2.0 - 1.0
}

// ---------------------------------------------------------------------------
// Analytic masks (canonical coords: u right, v down)
// ---------------------------------------------------------------------------

#[inline]
fn rect(u: f32, v: f32, cx: f32, cy: f32, hw: f32, hh: f32) -> bool {
    (u - cx).abs() <= hw && (v - cy).abs() <= hh
}

#[inline]
fn ellipse(u: f32, v: f32, cx: f32, cy: f32, ru: f32, rv: f32) -> bool {
    let du = (u - cx) / ru;
    let dv = (v - cy) / rv;
    du * du + dv * dv <= 1.0
}

#[inline]
fn circle(u: f32, v: f32, cx: f32, cy: f32, r: f32) -> bool {
    ellipse(u, v, cx, cy, r, r)
}

#[inline]
fn ring(u: f32, v: f32, cx: f32, cy: f32, r: f32, w: f32) -> bool {
    let d2 = (u - cx) * (u - cx) + (v - cy) * (v - cy);
    d2 <= (r + w) * (r + w) && d2 >= (r - w) * (r - w)
}

#[inline]
fn seg(u: f32, v: f32, x1: f32, y1: f32, x2: f32, y2: f32, w: f32) -> bool {
    let (dx, dy) = (x2 - x1, y2 - y1);
    let ll = (dx * dx + dy * dy).max(1e-9);
    let t = (((u - x1) * dx + (v - y1) * dy) / ll).clamp(0.0, 1.0);
    let (px, py) = (x1 + t * dx, y1 + t * dy);
    (u - px) * (u - px) + (v - py) * (v - py) <= w * w
}

/// Evaluate the ordered layer list for `cls` at canonical point (u, v);
/// returns the colour of the topmost hit layer, if any. Must mirror
/// data.py::class_layers (same geometry constants, same order).
fn layer_colour(cls: ClassId, u: f32, v: f32, base: [f32; 3], accent: [f32; 3]) -> Option<[f32; 3]> {
    let b = base;
    let a = accent;
    let w = WHEEL;
    // Layers are painted in order; the *last* hit wins, so scan in reverse.
    macro_rules! layers {
        ($(($m:expr, $c:expr)),+ $(,)?) => {{
            let ls: &[(bool, [f32; 3])] = &[$(($m, $c)),+];
            ls.iter().rev().find(|(hit, _)| *hit).map(|(_, c)| *c)
        }};
    }
    match cls {
        ClassId::Car => layers![
            (rect(u, v, 0.0, 0.08, 0.72, 0.26), b),
            (rect(u, v, -0.05, -0.22, 0.36, 0.16), a),
            (circle(u, v, -0.42, 0.42, 0.16), w),
            (circle(u, v, 0.42, 0.42, 0.16), w),
        ],
        ClassId::Bus => layers![
            (rect(u, v, 0.0, 0.0, 0.85, 0.45), b),
            (rect(u, v, 0.0, -0.2, 0.75, 0.1), a),
            (circle(u, v, -0.5, 0.5, 0.14), w),
            (circle(u, v, 0.5, 0.5, 0.14), w),
        ],
        ClassId::Truck => layers![
            (rect(u, v, -0.58, 0.0, 0.2, 0.3), a),
            (rect(u, v, 0.18, -0.08, 0.55, 0.38), b),
            (circle(u, v, -0.58, 0.42, 0.13), w),
            (circle(u, v, 0.05, 0.44, 0.13), w),
            (circle(u, v, 0.6, 0.44, 0.13), w),
        ],
        ClassId::Moped => layers![
            (circle(u, v, -0.45, 0.42, 0.2), w),
            (circle(u, v, 0.45, 0.42, 0.2), w),
            (rect(u, v, 0.08, 0.08, 0.28, 0.2), b),
            (seg(u, v, 0.35, -0.3, 0.3, 0.1, 0.06), a),
            (rect(u, v, 0.35, -0.35, 0.14, 0.05), a),
            (rect(u, v, -0.28, -0.1, 0.16, 0.07), b),
        ],
        ClassId::Bicycle => layers![
            (ring(u, v, -0.45, 0.32, 0.3, 0.07), w),
            (ring(u, v, 0.45, 0.32, 0.3, 0.07), w),
            (seg(u, v, -0.45, 0.32, 0.05, -0.3, 0.05), b),
            (seg(u, v, 0.05, -0.3, 0.45, 0.32, 0.05), b),
            (seg(u, v, -0.45, 0.32, 0.0, 0.32, 0.05), b),
            (rect(u, v, 0.05, -0.38, 0.12, 0.04), a),
        ],
        ClassId::Person => layers![
            (rect(u, v, -0.1, 0.55, 0.08, 0.3), a),
            (rect(u, v, 0.12, 0.55, 0.08, 0.3), a),
            (ellipse(u, v, 0.0, -0.02, 0.24, 0.38), b),
            (circle(u, v, 0.0, -0.56, 0.18), a),
        ],
        ClassId::Dog => layers![
            (rect(u, v, -0.3, 0.5, 0.06, 0.22), b),
            (rect(u, v, 0.3, 0.5, 0.06, 0.22), b),
            (ellipse(u, v, 0.0, 0.12, 0.48, 0.24), b),
            (circle(u, v, 0.52, -0.1, 0.17), b),
            (seg(u, v, -0.48, 0.0, -0.68, -0.3, 0.05), b),
        ],
        ClassId::Cart => layers![
            (rect(u, v, 0.1, -0.02, 0.48, 0.3), b),
            (circle(u, v, 0.1, 0.45, 0.18), w),
            (seg(u, v, -0.38, -0.1, -0.75, -0.45, 0.05), a),
        ],
    }
}

/// Rasterise one sprite on its background: `(size, size, 3)` f32 image.
pub fn render_sprite(p: &SpriteParams) -> Image {
    let s = p.size;
    let mut img = Image::filled(s, s, p.bg);
    let (cos_r, sin_r) = (p.rot.cos(), p.rot.sin());
    for y in 0..s {
        // half-pixel centres mapped to [-1, 1]
        let v = (2.0 * y as f32 + 1.0) / s as f32 - 1.0;
        for x in 0..s {
            let u = (2.0 * x as f32 + 1.0) / s as f32 - 1.0;
            let uc = u - p.jx;
            let vc = v - p.jy;
            let ur = uc * cos_r + vc * sin_r;
            let vr = -uc * sin_r + vc * cos_r;
            let mut px = if let Some(c) = layer_colour(p.cls, ur, vr, p.base, p.accent) {
                c
            } else {
                p.bg
            };
            if p.noise > 0.0 {
                for (ch, val) in px.iter_mut().enumerate() {
                    let seed = p.seed.wrapping_add((ch as u32).wrapping_mul(1_013_904_223));
                    *val += p.noise * pixel_noise(x as u32, y as u32, seed);
                }
            }
            img.set(y, x, [px[0].clamp(0.0, 1.0), px[1].clamp(0.0, 1.0), px[2].clamp(0.0, 1.0)]);
        }
    }
    img
}

/// Paint a sprite into a larger frame at integer offset `(oy, ox)` without
/// the sprite's own background: only pixels whose canonical-space mask hits
/// a layer are painted (background stays the frame's). Noise is applied to
/// painted pixels only.
pub fn paint_sprite(frame: &mut Image, p: &SpriteParams, oy: i64, ox: i64) {
    let s = p.size as i64;
    let (cos_r, sin_r) = (p.rot.cos(), p.rot.sin());
    for sy in 0..s {
        let fy = oy + sy;
        if fy < 0 || fy >= frame.h as i64 {
            continue;
        }
        let v = (2.0 * sy as f32 + 1.0) / s as f32 - 1.0;
        for sx in 0..s {
            let fx = ox + sx;
            if fx < 0 || fx >= frame.w as i64 {
                continue;
            }
            let u = (2.0 * sx as f32 + 1.0) / s as f32 - 1.0;
            let uc = u - p.jx;
            let vc = v - p.jy;
            let ur = uc * cos_r + vc * sin_r;
            let vr = -uc * sin_r + vc * cos_r;
            if let Some(mut c) = layer_colour(p.cls, ur, vr, p.base, p.accent) {
                if p.noise > 0.0 {
                    for (ch, val) in c.iter_mut().enumerate() {
                        let seed = p.seed.wrapping_add((ch as u32).wrapping_mul(1_013_904_223));
                        *val += p.noise * pixel_noise(sx as u32, sy as u32, seed);
                    }
                }
                frame.set(fy as usize, fx as usize, [
                    c[0].clamp(0.0, 1.0),
                    c[1].clamp(0.0, 1.0),
                    c[2].clamp(0.0, 1.0),
                ]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::NUM_CLASSES;

    fn demo_params(cls: ClassId) -> SpriteParams {
        SpriteParams {
            cls,
            size: 24,
            base: [0.8, 0.2, 0.2],
            accent: [0.2, 0.2, 0.8],
            bg: [0.5, 0.5, 0.5],
            rot: 0.0,
            jx: 0.0,
            jy: 0.0,
            noise: 0.0,
            seed: 0,
        }
    }

    #[test]
    fn hash32_pinned_values() {
        // Same pins as python/tests/test_data.py::test_hash32_pinned_values.
        assert_eq!(hash32(0), 0);
        assert_eq!(hash32(1), 1_753_845_952);
        assert_eq!(hash32(2), 3_507_691_905);
        assert_eq!(hash32(12_345), 2_435_775_735);
        assert_eq!(hash32(0xFFFF_FFFF), 1_734_902_346);
    }

    #[test]
    fn pixel_noise_bounded_and_deterministic() {
        let mut acc = 0.0;
        for y in 0..16u32 {
            for x in 0..16u32 {
                let n = pixel_noise(x, y, 42);
                assert!(n.abs() <= 1.0);
                assert_eq!(n, pixel_noise(x, y, 42));
                acc += (n as f64) * (n as f64);
            }
        }
        let std = (acc / 256.0).sqrt();
        assert!(std > 0.3, "noise degenerate: std {std}");
    }

    #[test]
    fn render_deterministic() {
        let p = demo_params(ClassId::Moped);
        assert_eq!(render_sprite(&p).data, render_sprite(&p).data);
    }

    #[test]
    fn classes_render_distinct() {
        let imgs: Vec<Image> = (0..NUM_CLASSES)
            .map(|i| render_sprite(&demo_params(ClassId::from_index(i).unwrap())))
            .collect();
        for i in 0..imgs.len() {
            for j in i + 1..imgs.len() {
                assert!(imgs[i].mad(&imgs[j]) > 0.005, "classes {i} vs {j} identical");
            }
        }
    }

    #[test]
    fn sprite_covers_sane_fraction() {
        for i in 0..NUM_CLASSES {
            let p = demo_params(ClassId::from_index(i).unwrap());
            let img = render_sprite(&p);
            let bg = Image::filled(p.size, p.size, p.bg);
            let mut hits = 0;
            for (a, b) in img.data.chunks_exact(3).zip(bg.data.chunks_exact(3)) {
                if (a[0] - b[0]).abs().max((a[1] - b[1]).abs()).max((a[2] - b[2]).abs()) > 0.05 {
                    hits += 1;
                }
            }
            let frac = hits as f64 / (p.size * p.size) as f64;
            assert!((0.05..0.9).contains(&frac), "class {i}: coverage {frac}");
        }
    }

    #[test]
    fn paint_respects_frame_bounds() {
        let mut frame = Image::filled(40, 60, [0.4, 0.4, 0.4]);
        let p = demo_params(ClassId::Car);
        // Paint partially outside — must not panic, must change some pixels.
        paint_sprite(&mut frame, &p, -10, 50);
        paint_sprite(&mut frame, &p, 20, 20);
        let base = Image::filled(40, 60, [0.4, 0.4, 0.4]);
        assert!(frame.mad(&base) > 0.0);
    }

    #[test]
    fn paint_leaves_background_untouched() {
        let mut frame = Image::filled(64, 64, [0.3, 0.6, 0.3]);
        let p = demo_params(ClassId::Person);
        paint_sprite(&mut frame, &p, 20, 20);
        // Far corner is untouched.
        assert_eq!(frame.at(0, 0), [0.3, 0.6, 0.3]);
        assert_eq!(frame.at(63, 63), [0.3, 0.6, 0.3]);
    }

    #[test]
    fn noise_changes_pixels() {
        let mut p = demo_params(ClassId::Bus);
        let clean = render_sprite(&p);
        p.noise = 0.1;
        let noisy = render_sprite(&p);
        assert!(clean.mad(&noisy) > 0.0);
    }
}
