//! Synthetic surveillance-video substrate.
//!
//! Stand-in for the paper's 170 h of YouTube-live footage (DESIGN.md §3):
//! each camera has a [`SceneSpec`] — an object-class mix (which makes
//! cameras *clusterable*, paper §III-A) and a busy-hour schedule (which
//! creates the heterogeneous load the task allocator exploits, §IV-D).
//! Frames are real pixel buffers: moving sprites over a static background,
//! produced by the same analytic renderer the CNNs were trained on.

pub mod sprite;

use crate::testkit::Rng;
use crate::types::{CameraId, ClassId, Frame, Image, NUM_CLASSES};
use sprite::{paint_sprite, SpriteParams};

/// Scene archetypes observed by the paper: roads produce vehicles, squares
/// produce pedestrians. The class-mix vectors below are the ground truth
/// the offline profiling stage should (approximately) recover.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SceneKind {
    /// Major road: cars/buses/trucks dominate; mopeds/bicycles present.
    Road,
    /// Square / walking trail: persons/dogs dominate; some bicycles/carts.
    Square,
}

impl SceneKind {
    /// Ground-truth object mix (unnormalised weights per class).
    pub fn class_mix(self) -> [f64; NUM_CLASSES] {
        match self {
            // car, bus, truck, moped, bicycle, person, dog, cart
            SceneKind::Road => [0.34, 0.12, 0.14, 0.16, 0.10, 0.08, 0.02, 0.04],
            SceneKind::Square => [0.05, 0.02, 0.02, 0.08, 0.16, 0.38, 0.17, 0.12],
        }
    }
}

/// Per-camera scene description.
#[derive(Clone, Debug)]
pub struct SceneSpec {
    pub camera: CameraId,
    pub kind: SceneKind,
    /// Background colour (roughly constant per camera; cameras are static).
    pub bg: [f32; 3],
    /// Busy-hour schedule: mean object arrivals per second as a periodic
    /// function of time. `base_rate` off-peak, `busy_rate` inside the busy
    /// window `[busy_start, busy_start + busy_len)` (mod `period`).
    pub period: f64,
    pub busy_start: f64,
    pub busy_len: f64,
    pub base_rate: f64,
    pub busy_rate: f64,
    /// Sensor noise amplitude added to sprites.
    pub noise: f32,
    pub seed: u64,
}

impl SceneSpec {
    /// Object arrival rate (objects/sec entering the scene) at time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        let phase = t.rem_euclid(self.period);
        let in_busy = if self.busy_start + self.busy_len <= self.period {
            phase >= self.busy_start && phase < self.busy_start + self.busy_len
        } else {
            // busy window wraps around the period boundary
            phase >= self.busy_start || phase < (self.busy_start + self.busy_len) - self.period
        };
        if in_busy {
            self.busy_rate
        } else {
            self.base_rate
        }
    }
}

/// A sprite moving through the scene along a straight path.
#[derive(Clone, Debug)]
struct Actor {
    params: SpriteParams,
    /// Position of the sprite's top-left corner at spawn (pixels).
    y0: f64,
    x0: f64,
    /// Velocity in pixels/sec.
    vy: f64,
    vx: f64,
    t_spawn: f64,
    /// Actor leaves the scene after this long.
    ttl: f64,
}

impl Actor {
    fn pos_at(&self, t: f64) -> (i64, i64) {
        let dt = t - self.t_spawn;
        ((self.y0 + self.vy * dt) as i64, (self.x0 + self.vx * dt) as i64)
    }

    fn alive_at(&self, t: f64) -> bool {
        t >= self.t_spawn && t < self.t_spawn + self.ttl
    }
}

/// Deterministic synthetic camera: produces frames on demand at any
/// timestamp. Object arrivals follow a Poisson process whose rate tracks
/// the busy-hour schedule; each object crosses the scene along a line.
pub struct Camera {
    pub spec: SceneSpec,
    pub frame_h: usize,
    pub frame_w: usize,
    background: Image,
    actors: Vec<Actor>,
    /// Arrival process has been materialised up to this time.
    horizon: f64,
    rng: Rng,
    seq: u64,
}

impl Camera {
    pub fn new(spec: SceneSpec, frame_h: usize, frame_w: usize) -> Camera {
        let mut rng = Rng::new(spec.seed);
        let mut background = Image::filled(frame_h, frame_w, spec.bg);
        // Mild static vertical gradient so the background is not flat.
        for y in 0..frame_h {
            let g = 0.03 * (y as f32 / frame_h as f32 - 0.5);
            for x in 0..frame_w {
                let px = background.at(y, x);
                background.set(y, x, [
                    (px[0] + g).clamp(0.0, 1.0),
                    (px[1] + g).clamp(0.0, 1.0),
                    (px[2] + g).clamp(0.0, 1.0),
                ]);
            }
        }
        let _ = rng.next_u64();
        Camera { spec, frame_h, frame_w, background, actors: Vec::new(), horizon: 0.0, rng, seq: 0 }
    }

    /// Sample a colour that stays away from the background colour so every
    /// object is detectable in principle.
    fn sample_colour(rng: &mut Rng) -> [f32; 3] {
        [rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95)]
    }

    fn spawn_actor(&mut self, t: f64) -> Actor {
        let mix = self.spec.kind.class_mix();
        let cls = ClassId::from_index(self.rng.weighted(&mix)).unwrap();
        let size = self.rng.range_usize(14, 31);
        let params = SpriteParams {
            cls,
            size,
            base: Self::sample_colour(&mut self.rng),
            accent: Self::sample_colour(&mut self.rng),
            bg: self.spec.bg,
            rot: self.rng.range_f32(-0.35, 0.35),
            jx: self.rng.range_f32(-0.12, 0.12),
            jy: self.rng.range_f32(-0.12, 0.12),
            noise: self.rng.range_f32(0.02, self.spec.noise.max(0.03)),
            seed: self.rng.next_u32(),
        };
        // Cross the scene horizontally (vehicles) or diagonally (others).
        let going_right = self.rng.bool(0.5);
        let speed = self.rng.range_f64(8.0, 28.0); // px/sec
        let y0 = self.rng.range_f64(0.0, (self.frame_h - size).max(1) as f64);
        let (x0, vx) = if going_right {
            (-(size as f64), speed)
        } else {
            (self.frame_w as f64, -speed)
        };
        let vy = self.rng.range_f64(-3.0, 3.0);
        let ttl = (self.frame_w as f64 + 2.0 * size as f64) / speed;
        Actor { params, y0, x0, vy, vx, t_spawn: t, ttl }
    }

    /// Materialise the Poisson arrival process up to `t` (thinning over the
    /// piecewise-constant rate, stepped at 1 s granularity).
    fn extend_horizon(&mut self, t: f64) {
        while self.horizon < t {
            let rate = self.spec.rate_at(self.horizon).max(1e-9);
            let step = self.horizon + 1.0;
            let mut at = self.horizon;
            loop {
                at += self.rng.exp(rate);
                if at >= step {
                    break;
                }
                let actor = self.spawn_actor(at);
                self.actors.push(actor);
            }
            self.horizon = step;
            // Garbage-collect long-dead actors to bound memory.
            let cutoff = self.horizon - 120.0;
            self.actors.retain(|a| a.t_spawn + a.ttl > cutoff);
        }
    }

    /// Render the frame at time `t`. Deterministic for a given spec/seed
    /// provided frames are requested with non-decreasing `t` (the arrival
    /// process is materialised incrementally).
    pub fn frame_at(&mut self, t: f64) -> Frame {
        self.extend_horizon(t);
        let mut image = self.background.clone();
        let actors: Vec<(SpriteParams, i64, i64)> = self
            .actors
            .iter()
            .filter(|a| a.alive_at(t))
            .map(|a| {
                let (y, x) = a.pos_at(t);
                (a.params.clone(), y, x)
            })
            .collect();
        for (params, y, x) in &actors {
            paint_sprite(&mut image, params, *y, *x);
        }
        let seq = self.seq;
        self.seq += 1;
        Frame { camera: self.spec.camera, seq, t_capture: t, image }
    }

    /// Ground-truth objects visible at time `t` (class + bbox), for metric
    /// purposes. Bboxes are the sprite canvases clipped to the frame.
    pub fn truth_at(&mut self, t: f64) -> Vec<(ClassId, crate::types::BBox)> {
        self.extend_horizon(t);
        self.actors
            .iter()
            .filter(|a| a.alive_at(t))
            .filter_map(|a| {
                let (y, x) = a.pos_at(t);
                let s = a.params.size as i64;
                let y0 = y.max(0);
                let x0 = x.max(0);
                let y1 = (y + s).min(self.frame_h as i64);
                let x1 = (x + s).min(self.frame_w as i64);
                if y1 <= y0 || x1 <= x0 {
                    return None;
                }
                Some((
                    a.params.cls,
                    crate::types::BBox {
                        y0: y0 as usize,
                        x0: x0 as usize,
                        y1: y1 as usize,
                        x1: x1 as usize,
                    },
                ))
            })
            .collect()
    }
}

/// Build a standard deployment: `n` cameras alternating Road/Square scenes
/// with staggered busy periods (per paper §III-A, busy times differ across
/// scenes, which is what gives the allocator headroom).
pub fn standard_deployment(n: usize, frame_h: usize, frame_w: usize, seed: u64) -> Vec<Camera> {
    let mut master = Rng::new(seed);
    // Spawn rates are expressed as a target number of *visible* objects
    // per camera (what drives the per-sample task rate) and converted to
    // arrival rates via the mean crossing time, so the load regime is
    // independent of the frame resolution.
    let crossing = frame_w as f64 / 18.0; // mean px/s of actors ~ 18
    let base_visible = 0.25;
    let busy_visible = 1.6;
    (0..n)
        .map(|i| {
            let kind = if i % 2 == 0 { SceneKind::Road } else { SceneKind::Square };
            let mut rng = master.fork(i as u64);
            let period = 120.0;
            let spec = SceneSpec {
                camera: CameraId(i as u32),
                kind,
                bg: [
                    0.42 + rng.range_f32(-0.08, 0.08),
                    0.45 + rng.range_f32(-0.08, 0.08),
                    0.42 + rng.range_f32(-0.08, 0.08),
                ],
                period,
                // Stagger busy windows around the period so different
                // cameras peak at different times.
                busy_start: (i as f64 / n.max(1) as f64) * period,
                busy_len: period / 3.0,
                // Spawn rates chosen so that (with ~7 s crossing times and
                // 1 s sampling) an edge serving 4 cameras sits just under
                // its service capacity off-peak and ~2x over it during the
                // busy window — the operating regime of the paper's
                // evaluation (queues accumulate in edge-only / fixed, the
                // allocator drains them in SurveilEdge).
                base_rate: base_visible / crossing,
                busy_rate: busy_visible / crossing,
                noise: 0.12,
                seed: rng.next_u64(),
            };
            Camera::new(spec, frame_h, frame_w)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    fn test_spec(seed: u64) -> SceneSpec {
        SceneSpec {
            camera: CameraId(1),
            kind: SceneKind::Road,
            bg: [0.45, 0.47, 0.44],
            period: 60.0,
            busy_start: 20.0,
            busy_len: 20.0,
            base_rate: 0.2,
            busy_rate: 1.5,
            noise: 0.1,
            seed,
        }
    }

    #[test]
    fn rate_schedule_periodic() {
        let s = test_spec(1);
        assert_eq!(s.rate_at(0.0), 0.2);
        assert_eq!(s.rate_at(25.0), 1.5);
        assert_eq!(s.rate_at(45.0), 0.2);
        assert_eq!(s.rate_at(60.0 + 25.0), 1.5);
        assert_eq!(s.rate_at(600.0 + 5.0), 0.2);
    }

    #[test]
    fn rate_schedule_wrapping_window() {
        let mut s = test_spec(1);
        s.busy_start = 50.0;
        s.busy_len = 20.0; // wraps: busy in [50,60) U [0,10)
        assert_eq!(s.rate_at(55.0), 1.5);
        assert_eq!(s.rate_at(5.0), 1.5);
        assert_eq!(s.rate_at(15.0), 0.2);
        assert_eq!(s.rate_at(49.0), 0.2);
    }

    #[test]
    fn frames_have_motion() {
        let mut cam = Camera::new(test_spec(7), 96, 128);
        // Warm up past a busy window so actors exist.
        let a = cam.frame_at(30.0);
        let b = cam.frame_at(31.0);
        assert_eq!(a.image.h, 96);
        assert_eq!(b.seq, a.seq + 1);
        // With rate 1.5/s in the busy window, motion is near-certain.
        assert!(a.image.mad(&b.image) > 0.0, "no motion between consecutive frames");
    }

    #[test]
    fn truth_matches_painted_objects() {
        let mut cam = Camera::new(test_spec(9), 96, 128);
        let t = 30.0;
        let frame = cam.frame_at(t);
        let truth = cam.truth_at(t);
        // Every ground-truth bbox region must differ from the background.
        let bgframe = Camera::new(test_spec(9), 96, 128).frame_at(0.0);
        for (_, bb) in &truth {
            let region = frame.image.crop(bb.y0, bb.x0, bb.y1, bb.x1);
            let bgregion = bgframe.image.crop(bb.y0, bb.x0, bb.y1, bb.x1);
            assert!(region.mad(&bgregion) > 0.0, "truth bbox {bb:?} not painted");
        }
    }

    #[test]
    fn class_mix_matches_scene_kind() {
        // Spawn many actors from a Road camera; vehicle classes dominate.
        let mut cam = Camera::new(test_spec(11), 96, 128);
        cam.extend_horizon(400.0);
        let total = cam.actors.len().max(1);
        let vehicles = cam
            .actors
            .iter()
            .filter(|a| {
                matches!(a.params.cls, ClassId::Car | ClassId::Bus | ClassId::Truck | ClassId::Moped)
            })
            .count();
        let frac = vehicles as f64 / total as f64;
        assert!(frac > 0.5, "road camera vehicle fraction {frac}");
    }

    #[test]
    fn deployment_staggers_busy_windows() {
        let cams = standard_deployment(4, 48, 64, 3);
        let starts: Vec<f64> = cams.iter().map(|c| c.spec.busy_start).collect();
        for i in 0..starts.len() {
            for j in i + 1..starts.len() {
                assert!((starts[i] - starts[j]).abs() > 1.0);
            }
        }
    }

    #[test]
    fn prop_arrivals_scale_with_rate() {
        check("arrivals_scale_with_rate", |rng, _| {
            let mut spec = test_spec(rng.next_u64());
            spec.base_rate = rng.range_f64(0.05, 0.3);
            spec.busy_rate = spec.base_rate * rng.range_f64(3.0, 8.0);
            let mut cam = Camera::new(spec.clone(), 48, 64);
            cam.extend_horizon(240.0);
            // Count arrivals in busy vs off-peak phases.
            let (mut busy, mut idle) = (0usize, 0usize);
            for a in &cam.actors {
                if spec.rate_at(a.t_spawn) == spec.busy_rate {
                    busy += 1;
                } else {
                    idle += 1;
                }
            }
            // Busy window is 1/3 of the period at >=3x the rate: busy
            // arrivals should clearly outnumber half the idle arrivals.
            assert!(busy + idle > 0);
            if idle > 20 {
                let busy_rate_measured = busy as f64 / 80.0; // 80 busy secs in 240
                let idle_rate_measured = idle as f64 / 160.0;
                assert!(
                    busy_rate_measured > idle_rate_measured,
                    "busy {busy_rate_measured} <= idle {idle_rate_measured}"
                );
            }
        });
    }
}
