//! Workload traces: arrival processes + record/replay.
//!
//! Benches need repeatable workloads. A [`TraceRecord`] is the sequence of
//! classification tasks a scenario produced (camera, time, true class,
//! edge-CNN confidence, crop bytes); benches replay it through scheduler
//! variants so every scheme sees the *identical* workload — the same trick
//! the paper uses by replaying recorded video through each system variant.

use crate::testkit::Rng;
use crate::types::{CameraId, ClassId};

/// One recorded classification task.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceTask {
    /// Arrival time of the task at its home edge (seconds).
    pub t_arrival: f64,
    pub camera: CameraId,
    /// Home edge index (1-based; 0 is the cloud).
    pub home_edge: u32,
    pub truth: ClassId,
    /// Edge-CNN confidence that this is the query object.
    pub confidence: f32,
    /// What the ground-truth (cloud) CNN answers.
    pub oracle_positive: bool,
    /// Upload size if sent to the cloud.
    pub crop_bytes: u64,
}

/// A full workload trace.
#[derive(Clone, Debug, Default)]
pub struct TraceRecord {
    pub tasks: Vec<TraceTask>,
}

impl TraceRecord {
    pub fn push(&mut self, t: TraceTask) {
        self.tasks.push(t);
    }

    /// Tasks sorted by arrival time (stable).
    pub fn sorted(mut self) -> TraceRecord {
        self.tasks
            .sort_by(|a, b| a.t_arrival.partial_cmp(&b.t_arrival).unwrap());
        self
    }

    pub fn duration(&self) -> f64 {
        self.tasks.iter().map(|t| t.t_arrival).fold(0.0, f64::max)
    }

    /// Serialize to a simple line format (CSV) for EXPERIMENTS.md dumps.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_arrival,camera,home_edge,truth,confidence,oracle,bytes\n");
        for t in &self.tasks {
            out.push_str(&format!(
                "{:.4},{},{},{},{:.4},{},{}\n",
                t.t_arrival,
                t.camera.0,
                t.home_edge,
                t.truth.index(),
                t.confidence,
                t.oracle_positive as u8,
                t.crop_bytes
            ));
        }
        out
    }

    /// Parse the CSV format back (replay from disk).
    pub fn from_csv(s: &str) -> Option<TraceRecord> {
        let mut tasks = Vec::new();
        for line in s.lines().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 7 {
                return None;
            }
            tasks.push(TraceTask {
                t_arrival: f[0].parse().ok()?,
                camera: CameraId(f[1].parse().ok()?),
                home_edge: f[2].parse().ok()?,
                truth: ClassId::from_index(f[3].parse().ok()?)?,
                confidence: f[4].parse().ok()?,
                oracle_positive: f[5] == "1",
                crop_bytes: f[6].parse().ok()?,
            });
        }
        Some(TraceRecord { tasks })
    }
}

/// Parameters of a synthetic trace (used by benches that don't need pixel
/// frames: the confidence distribution stands in for the edge CNN).
#[derive(Clone, Debug)]
pub struct SyntheticTraceSpec {
    pub n_edges: u32,
    pub cams_per_edge: u32,
    pub duration: f64,
    /// Busy-period schedule per edge: staggered as in the video substrate.
    pub period: f64,
    pub base_rate: f64,
    pub busy_rate: f64,
    pub query: ClassId,
    /// Probability the query object appears among arrivals.
    pub positive_frac: f64,
    /// Edge-CNN quality: confidence ~ Beta-like around the truth.
    pub edge_sharpness: f64,
    pub crop_bytes: u64,
    pub seed: u64,
}

impl Default for SyntheticTraceSpec {
    fn default() -> SyntheticTraceSpec {
        SyntheticTraceSpec {
            n_edges: 1,
            cams_per_edge: 4,
            duration: 300.0,
            period: 120.0,
            base_rate: 0.1,
            busy_rate: 0.6,
            query: ClassId::Moped,
            positive_frac: 0.18,
            edge_sharpness: 4.0,
            crop_bytes: 24 * 24 * 3,
            seed: 7,
        }
    }
}

/// Draw an edge-CNN-like confidence: positives cluster near 1, negatives
/// near 0, with overlap controlled by `sharpness` (higher = better CNN).
pub fn synth_confidence(rng: &mut Rng, positive: bool, sharpness: f64) -> f32 {
    // Sample from a Kumaraswamy(a,b)-style curve: cheap, bounded, skewed.
    let u = rng.f64().max(1e-9);
    let x = u.powf(1.0 / sharpness);
    let f = if positive { x } else { 1.0 - x };
    f as f32
}

/// Generate a synthetic trace with staggered busy windows per edge.
pub fn generate(spec: &SyntheticTraceSpec) -> TraceRecord {
    let mut rng = Rng::new(spec.seed);
    let mut rec = TraceRecord::default();
    for e in 0..spec.n_edges {
        let busy_start = spec.period * (e as f64 / spec.n_edges.max(1) as f64);
        let busy_len = spec.period / 3.0;
        for c in 0..spec.cams_per_edge {
            let cam = CameraId(e * spec.cams_per_edge + c);
            let mut stream = rng.fork((e as u64) << 32 | c as u64);
            let mut t = 0.0;
            while t < spec.duration {
                let phase = t.rem_euclid(spec.period);
                let rate = if phase >= busy_start && phase < busy_start + busy_len {
                    spec.busy_rate
                } else {
                    spec.base_rate
                };
                t += stream.exp(rate.max(1e-9));
                if t >= spec.duration {
                    break;
                }
                let positive = stream.bool(spec.positive_frac);
                let truth = if positive {
                    spec.query
                } else {
                    // any non-query class
                    loop {
                        let c = ClassId::from_index(stream.range_usize(0, 8)).unwrap();
                        if c != spec.query {
                            break c;
                        }
                    }
                };
                let confidence = synth_confidence(&mut stream, positive, spec.edge_sharpness);
                // The oracle (cloud CNN) is right ~99% of the time.
                let oracle_positive = if stream.bool(0.99) { positive } else { !positive };
                rec.push(TraceTask {
                    t_arrival: t,
                    camera: cam,
                    home_edge: e + 1,
                    truth,
                    confidence,
                    oracle_positive,
                    crop_bytes: spec.crop_bytes,
                });
            }
        }
    }
    rec.sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn generate_sorted_and_bounded() {
        let spec = SyntheticTraceSpec { duration: 100.0, ..Default::default() };
        let rec = generate(&spec);
        assert!(!rec.tasks.is_empty());
        for w in rec.tasks.windows(2) {
            assert!(w[0].t_arrival <= w[1].t_arrival);
        }
        assert!(rec.duration() < 100.0);
    }

    #[test]
    fn generate_deterministic() {
        let spec = SyntheticTraceSpec::default();
        assert_eq!(generate(&spec).tasks, generate(&spec).tasks);
    }

    #[test]
    fn positives_roughly_match_fraction() {
        let spec = SyntheticTraceSpec { duration: 2000.0, ..Default::default() };
        let rec = generate(&spec);
        let pos = rec.tasks.iter().filter(|t| t.truth == spec.query).count();
        let frac = pos as f64 / rec.tasks.len() as f64;
        assert!((frac - spec.positive_frac).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn confidences_separate_classes() {
        let mut rng = Rng::new(3);
        let pos_mean: f64 = (0..2000)
            .map(|_| synth_confidence(&mut rng, true, 4.0) as f64)
            .sum::<f64>()
            / 2000.0;
        let neg_mean: f64 = (0..2000)
            .map(|_| synth_confidence(&mut rng, false, 4.0) as f64)
            .sum::<f64>()
            / 2000.0;
        assert!(pos_mean > 0.7, "pos {pos_mean}");
        assert!(neg_mean < 0.3, "neg {neg_mean}");
    }

    #[test]
    fn sharpness_controls_overlap() {
        let mut rng = Rng::new(4);
        let err = |sharp: f64, rng: &mut Rng| -> f64 {
            let n = 2000;
            let wrong = (0..n)
                .filter(|i| {
                    let positive = i % 2 == 0;
                    let f = synth_confidence(rng, positive, sharp);
                    (f >= 0.5) != positive
                })
                .count();
            wrong as f64 / n as f64
        };
        let sloppy = err(1.5, &mut rng);
        let sharp = err(8.0, &mut rng);
        assert!(sharp < sloppy, "sharp {sharp} vs sloppy {sloppy}");
    }

    #[test]
    fn csv_roundtrip() {
        let spec = SyntheticTraceSpec { duration: 50.0, ..Default::default() };
        let rec = generate(&spec);
        let csv = rec.to_csv();
        let back = TraceRecord::from_csv(&csv).expect("parse");
        assert_eq!(rec.tasks.len(), back.tasks.len());
        for (a, b) in rec.tasks.iter().zip(back.tasks.iter()) {
            assert_eq!(a.camera, b.camera);
            assert_eq!(a.truth, b.truth);
            assert_eq!(a.oracle_positive, b.oracle_positive);
            assert!((a.t_arrival - b.t_arrival).abs() < 1e-3);
            assert!((a.confidence - b.confidence).abs() < 1e-3);
        }
    }

    #[test]
    fn from_csv_rejects_malformed() {
        assert!(TraceRecord::from_csv("header\n1,2,3\n").is_none());
        let empty = TraceRecord::from_csv("header only\n").unwrap();
        assert!(empty.tasks.is_empty());
    }

    #[test]
    fn prop_busy_windows_stagger_load() {
        check("trace_busy_stagger", |rng, _| {
            let spec = SyntheticTraceSpec {
                n_edges: 3,
                duration: 360.0,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let rec = generate(&spec);
            // Each edge must have tasks, and arrival counts must differ
            // across phases for at least one edge (busy periods exist).
            for e in 1..=3u32 {
                let n = rec.tasks.iter().filter(|t| t.home_edge == e).count();
                assert!(n > 0, "edge {e} got no tasks");
            }
        });
    }
}
