//! Core domain types shared by every subsystem.

use std::fmt;

/// Object classes — must stay in sync with `python/compile/data.py::CLASSES`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ClassId {
    Car = 0,
    Bus = 1,
    Truck = 2,
    Moped = 3,
    Bicycle = 4,
    Person = 5,
    Dog = 6,
    Cart = 7,
}

pub const NUM_CLASSES: usize = 8;
pub const CLASS_NAMES: [&str; NUM_CLASSES] =
    ["car", "bus", "truck", "moped", "bicycle", "person", "dog", "cart"];

impl ClassId {
    pub fn from_index(i: usize) -> Option<ClassId> {
        use ClassId::*;
        [Car, Bus, Truck, Moped, Bicycle, Person, Dog, Cart].get(i).copied()
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        CLASS_NAMES[self.index()]
    }

    pub fn from_name(name: &str) -> Option<ClassId> {
        CLASS_NAMES.iter().position(|n| *n == name).and_then(ClassId::from_index)
    }
}

impl fmt::Display for ClassId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Identifies a camera in the deployment.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct CameraId(pub u32);

/// Identifies a compute node. Per the paper, node `0` is the Cloud and
/// `1..=N` are edge devices.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct NodeId(pub u32);

impl NodeId {
    pub const CLOUD: NodeId = NodeId(0);

    pub fn is_cloud(self) -> bool {
        self.0 == 0
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_cloud() {
            write!(f, "cloud")
        } else {
            write!(f, "edge{}", self.0)
        }
    }
}

/// An RGB f32 image in row-major HWC layout, values in `[0, 1]`.
#[derive(Clone, PartialEq, Debug)]
pub struct Image {
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>, // h * w * 3
}

impl Image {
    pub fn new(h: usize, w: usize) -> Image {
        Image { h, w, data: vec![0.0; h * w * 3] }
    }

    pub fn filled(h: usize, w: usize, rgb: [f32; 3]) -> Image {
        let mut img = Image::new(h, w);
        for px in img.data.chunks_exact_mut(3) {
            px.copy_from_slice(&rgb);
        }
        img
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize) -> [f32; 3] {
        let i = (y * self.w + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, rgb: [f32; 3]) {
        let i = (y * self.w + x) * 3;
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    /// Extract the sub-image `[y0, y1) x [x0, x1)` (clamped to bounds).
    pub fn crop(&self, y0: usize, x0: usize, y1: usize, x1: usize) -> Image {
        let y1 = y1.min(self.h);
        let x1 = x1.min(self.w);
        let (y0, x0) = (y0.min(y1), x0.min(x1));
        let mut out = Image::new(y1 - y0, x1 - x0);
        for y in y0..y1 {
            let src = (y * self.w + x0) * 3;
            let dst = ((y - y0) * out.w) * 3;
            out.data[dst..dst + out.w * 3].copy_from_slice(&self.data[src..src + out.w * 3]);
        }
        out
    }

    /// Bilinear resize (half-pixel-centre convention, edge clamp) — the
    /// exact algorithm of `python/compile/data.py::bilinear_resize`, so the
    /// serving crop distribution matches the training distribution.
    pub fn resize(&self, oh: usize, ow: usize) -> Image {
        let mut out = Image::new(oh, ow);
        let ry = self.h as f32 / oh as f32;
        let rx = self.w as f32 / ow as f32;
        for oy in 0..oh {
            let sy = (oy as f32 + 0.5) * ry - 0.5;
            let y0 = sy.floor().clamp(0.0, (self.h - 1) as f32) as usize;
            let y1 = (y0 + 1).min(self.h - 1);
            let fy = (sy - y0 as f32).clamp(0.0, 1.0);
            for ox in 0..ow {
                let sx = (ox as f32 + 0.5) * rx - 0.5;
                let x0 = sx.floor().clamp(0.0, (self.w - 1) as f32) as usize;
                let x1 = (x0 + 1).min(self.w - 1);
                let fx = (sx - x0 as f32).clamp(0.0, 1.0);
                let a = self.at(y0, x0);
                let b = self.at(y0, x1);
                let c = self.at(y1, x0);
                let d = self.at(y1, x1);
                let mut px = [0.0f32; 3];
                for ch in 0..3 {
                    let top = a[ch] * (1.0 - fx) + b[ch] * fx;
                    let bot = c[ch] * (1.0 - fx) + d[ch] * fx;
                    px[ch] = top * (1.0 - fy) + bot * fy;
                }
                out.set(oy, ox, px);
            }
        }
        out
    }

    /// Mean absolute per-pixel difference against another image.
    pub fn mad(&self, other: &Image) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        let sum: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .sum();
        sum / self.data.len() as f32
    }

    /// Size in bytes when transmitted (used by the bandwidth meter);
    /// models an 8-bit-per-channel encoding like the paper's JPEG crops.
    pub fn wire_bytes(&self) -> u64 {
        (self.h * self.w * 3) as u64
    }
}

/// A video frame from one camera.
#[derive(Clone, Debug)]
pub struct Frame {
    pub camera: CameraId,
    /// Sequence number within the stream.
    pub seq: u64,
    /// Capture timestamp (seconds since scenario start).
    pub t_capture: f64,
    pub image: Image,
}

/// Axis-aligned bounding box in pixel coordinates, `[y0, y1) x [x0, x1)`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct BBox {
    pub y0: usize,
    pub x0: usize,
    pub y1: usize,
    pub x1: usize,
}

impl BBox {
    pub fn height(&self) -> usize {
        self.y1.saturating_sub(self.y0)
    }

    pub fn width(&self) -> usize {
        self.x1.saturating_sub(self.x0)
    }

    pub fn area(&self) -> usize {
        self.height() * self.width()
    }

    pub fn aspect(&self) -> f32 {
        let h = self.height().max(1) as f32;
        let w = self.width().max(1) as f32;
        h.max(w) / h.min(w)
    }

    /// Intersection-over-union with another box.
    pub fn iou(&self, other: &BBox) -> f32 {
        let iy0 = self.y0.max(other.y0);
        let ix0 = self.x0.max(other.x0);
        let iy1 = self.y1.min(other.y1);
        let ix1 = self.x1.min(other.x1);
        if iy1 <= iy0 || ix1 <= ix0 {
            return 0.0;
        }
        let inter = ((iy1 - iy0) * (ix1 - ix0)) as f32;
        let union = (self.area() + other.area()) as f32 - inter;
        inter / union
    }

    /// Grow by `m` pixels on every side, clamped to `(h, w)`.
    pub fn expand(&self, m: usize, h: usize, w: usize) -> BBox {
        BBox {
            y0: self.y0.saturating_sub(m),
            x0: self.x0.saturating_sub(m),
            y1: (self.y1 + m).min(h),
            x1: (self.x1 + m).min(w),
        }
    }
}

/// A detected moving object: the classification work unit ("task" in the
/// paper). Carries the crop plus the routing/measurement metadata.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub camera: CameraId,
    pub frame_seq: u64,
    /// Frame capture time (query latency is measured from here).
    pub t_capture: f64,
    /// When the detector emitted the task.
    pub t_detected: f64,
    pub bbox: BBox,
    /// Crop already resized to the CNN input resolution.
    pub crop: Image,
    /// Ground-truth class of the dominant object (available because the
    /// substrate is synthetic; used for true-accuracy metrics only, never
    /// by the pipeline itself).
    pub truth: Option<ClassId>,
}

/// Where a task was ultimately classified.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Where {
    /// Answered by the CQ-specific CNN on this edge.
    Edge(NodeId),
    /// Uploaded (doubtful band) and re-classified by the cloud CNN.
    Cloud,
}

/// Final per-task query answer.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub task_id: u64,
    pub camera: CameraId,
    pub frame_seq: u64,
    /// Query-object decision.
    pub positive: bool,
    /// Edge-classifier confidence f (probability of query object).
    pub confidence: f32,
    pub decided_at: Where,
    /// End-to-end per-frame query latency (seconds).
    pub latency: f64,
    /// Ground truth positivity, if known.
    pub truth_positive: Option<bool>,
    /// What the ground-truth (cloud) CNN would answer — the paper measures
    /// accuracy against the cloud model.
    pub oracle_positive: Option<bool>,
}

/// A user query command (paper Fig. 1): object class + camera set.
#[derive(Clone, Debug)]
pub struct QueryCmd {
    pub object: ClassId,
    pub cameras: Vec<CameraId>,
    /// Sampling interval `s` in seconds (paper uses 1 s).
    pub interval: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_roundtrip() {
        for i in 0..NUM_CLASSES {
            let c = ClassId::from_index(i).unwrap();
            assert_eq!(c.index(), i);
            assert_eq!(ClassId::from_name(c.name()), Some(c));
        }
        assert!(ClassId::from_index(8).is_none());
        assert!(ClassId::from_name("boat").is_none());
    }

    #[test]
    fn node_id_cloud() {
        assert!(NodeId::CLOUD.is_cloud());
        assert!(!NodeId(3).is_cloud());
        assert_eq!(format!("{}", NodeId(0)), "cloud");
        assert_eq!(format!("{}", NodeId(2)), "edge2");
    }

    #[test]
    fn image_set_at_roundtrip() {
        let mut img = Image::new(4, 6);
        img.set(2, 3, [0.1, 0.2, 0.3]);
        assert_eq!(img.at(2, 3), [0.1, 0.2, 0.3]);
        assert_eq!(img.at(0, 0), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn image_crop_bounds() {
        let mut img = Image::new(8, 8);
        for y in 0..8 {
            for x in 0..8 {
                img.set(y, x, [y as f32, x as f32, 0.0]);
            }
        }
        let c = img.crop(2, 3, 5, 7);
        assert_eq!((c.h, c.w), (3, 4));
        assert_eq!(c.at(0, 0), [2.0, 3.0, 0.0]);
        assert_eq!(c.at(2, 3), [4.0, 6.0, 0.0]);
        // clamped
        let c2 = img.crop(6, 6, 20, 20);
        assert_eq!((c2.h, c2.w), (2, 2));
    }

    #[test]
    fn resize_identity() {
        let mut img = Image::new(5, 7);
        for i in 0..img.data.len() {
            img.data[i] = (i % 13) as f32 / 13.0;
        }
        let out = img.resize(5, 7);
        for (a, b) in img.data.iter().zip(&out.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn resize_constant_preserved() {
        let img = Image::filled(9, 4, [0.25, 0.5, 0.75]);
        let out = img.resize(32, 32);
        for px in out.data.chunks_exact(3) {
            assert!((px[0] - 0.25).abs() < 1e-6);
            assert!((px[1] - 0.5).abs() < 1e-6);
            assert!((px[2] - 0.75).abs() < 1e-6);
        }
    }

    #[test]
    fn bbox_geometry() {
        let b = BBox { y0: 2, x0: 4, y1: 10, x1: 8 };
        assert_eq!(b.height(), 8);
        assert_eq!(b.width(), 4);
        assert_eq!(b.area(), 32);
        assert!((b.aspect() - 2.0).abs() < 1e-6);
        assert!((b.iou(&b) - 1.0).abs() < 1e-6);
        let c = BBox { y0: 6, x0: 4, y1: 14, x1: 8 };
        let iou = b.iou(&c);
        assert!(iou > 0.0 && iou < 1.0);
        let far = BBox { y0: 100, x0: 100, y1: 110, x1: 110 };
        assert_eq!(b.iou(&far), 0.0);
    }

    #[test]
    fn bbox_expand_clamps() {
        let b = BBox { y0: 1, x0: 1, y1: 5, x1: 5 };
        let e = b.expand(3, 6, 6);
        assert_eq!(e, BBox { y0: 0, x0: 0, y1: 6, x1: 6 });
    }
}
