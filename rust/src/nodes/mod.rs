//! Edge and cloud node event loops (live threaded serving).
//!
//! An [`EdgeWorker`] owns the classification side of one edge device: it
//! classifies detected objects with the deployed CQ-specific CNN, applies
//! the [β, α] band, and uploads doubtful crops to the cloud over the bus.
//! The [`CloudWorker`] serves re-classification requests with the
//! high-accuracy CNN. Both publish verdicts on `verdict/#` and replicate
//! scheduler state (α, β, tᵢ, Qᵢ) through the [`crate::paramdb`].
//!
//! The experiment harness (`crate::harness`) drives the *same* per-task
//! stage code in discrete-event time for the paper's tables: both
//! substrates call `harness::pipeline::classify_stage` with a scheme
//! policy, and differ only in how they answer the stage's questions
//! (simulated queues vs atomics + wall-clock heartbeats). These workers
//! are what `examples/e2e_query.rs` runs live with real threads.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bus::{Broker, Message, QoS};
use crate::config::Scheme;
use crate::estimator::LatencyEstimator;
use crate::harness::{classify_stage, policy_for, EdgeAction, PipelineCtx};
use crate::metrics::{BandwidthMeter, Confusion, LatencyRecorder};
use crate::overload::{DegradationLadder, LoadLevel};
use crate::paramdb::{ParamDb, Value};
use crate::query::{QuerySet, QueryVerdict};
use crate::runtime::service::ServiceHandle;
use crate::sched::{NodeLoad, ThresholdController};
use crate::types::{ClassId, NodeId, Task, Verdict, Where};

/// Shared, thread-safe view of one node's scheduler state.
pub struct NodeState {
    pub id: NodeId,
    /// Queue length Qᵢ (tasks admitted, not yet answered).
    pub queue: AtomicU64,
    /// Latency estimator for tᵢ.
    pub estimator: Mutex<LatencyEstimator>,
}

impl NodeState {
    pub fn new(id: NodeId, initial_latency: f64) -> Arc<NodeState> {
        Arc::new(NodeState {
            id,
            queue: AtomicU64::new(0),
            estimator: Mutex::new(LatencyEstimator::new(initial_latency)),
        })
    }

    pub fn load(&self) -> NodeLoad {
        NodeLoad {
            node: self.id,
            queue: self.queue.load(Ordering::Relaxed) as usize,
            t_infer: self.estimator.lock().unwrap().estimate(),
            penalty: 0.0,
        }
    }

    /// Publish Qᵢ and tᵢ into the parameter DB (paper §IV-D-1: every
    /// update triggers replication).
    pub fn publish(&self, db: &ParamDb) {
        db.put(&ParamDb::key_q(self.id.0), Value::U64(self.queue.load(Ordering::Relaxed)));
        db.put(
            &ParamDb::key_t(self.id.0),
            Value::F64(self.estimator.lock().unwrap().estimate()),
        );
    }

    /// Publish a liveness heartbeat (`hb/<id>` = scenario seconds). Peers
    /// exclude this node from allocation once the value goes stale
    /// ([`crate::faults::HB_STALE_AFTER`]).
    pub fn heartbeat(&self, db: &ParamDb, now: f64) {
        db.put(&ParamDb::key_hb(self.id.0), Value::F64(now));
    }
}

/// Liveness from the replicated heartbeat key. A node that has *never*
/// heartbeated is presumed alive (cold start / heartbeats disabled), one
/// whose last beat is older than [`crate::faults::HB_STALE_AFTER`] is
/// treated as dead by the allocator until it beats again.
pub fn node_alive(db: &ParamDb, node: u32, now: f64) -> bool {
    db.get_f64(&ParamDb::key_hb(node))
        .is_none_or(|last| now - last <= crate::faults::HB_STALE_AFTER)
}

/// Build a final verdict for a task.
pub fn verdict_from(
    task: &Task,
    confidence: f32,
    positive: bool,
    decided_at: Where,
    now: f64,
    query: ClassId,
    oracle_positive: Option<bool>,
) -> Verdict {
    Verdict {
        task_id: task.id,
        camera: task.camera,
        frame_seq: task.frame_seq,
        positive,
        confidence,
        decided_at,
        latency: now - task.t_capture,
        truth_positive: task.truth.map(|t| t == query),
        oracle_positive,
    }
}

/// Aggregated per-run counters shared by the nodes.
#[derive(Default)]
pub struct RunMetrics {
    /// Accuracy vs the ground-truth (cloud) CNN — the paper's metric.
    pub vs_oracle: Mutex<Confusion>,
    /// Accuracy vs the synthetic ground truth (extra diagnostic).
    pub vs_truth: Mutex<Confusion>,
    pub latency: Mutex<LatencyRecorder>,
    pub bandwidth: Mutex<BandwidthMeter>,
    pub uploads: AtomicU64,
    pub answered_at_edge: AtomicU64,
    /// Tasks uploaded but not yet answered by the cloud — the l_d (d =
    /// cloud) term of the eq. 8 controller signal in live mode.
    pub cloud_backlog: AtomicU64,
    /// Doubtful crops answered with an edge-local verdict because the
    /// cloud's heartbeat was stale (graceful degradation: latency over
    /// accuracy, the §IV-D tradeoff taken to its failure-mode limit).
    pub degraded: AtomicU64,
    /// Tasks explicitly dropped by overload control (the degradation
    /// ladder's top rung) — never silently lost.
    pub shed: AtomicU64,
    /// Optional metric registry mirroring every recorded verdict
    /// ([`RunMetrics::attach_registry`]).
    obs: Mutex<Option<crate::obs::Registry>>,
}

impl RunMetrics {
    /// Mirror verdicts into a metric registry: a per-site counter plus a
    /// latency histogram (`site` = `edge` / `cloud`).
    pub fn attach_registry(&self, reg: crate::obs::Registry) {
        *self.obs.lock().unwrap() = Some(reg);
    }

    pub fn record_verdict(&self, v: &Verdict) {
        if let Some(oracle) = v.oracle_positive {
            self.vs_oracle.lock().unwrap().record(v.positive, oracle);
        }
        if let Some(truth) = v.truth_positive {
            self.vs_truth.lock().unwrap().record(v.positive, truth);
        }
        self.latency.lock().unwrap().record(v.latency);
        let site = match v.decided_at {
            Where::Edge(_) => {
                self.answered_at_edge.fetch_add(1, Ordering::Relaxed);
                "edge"
            }
            Where::Cloud => {
                self.uploads.fetch_add(1, Ordering::Relaxed);
                "cloud"
            }
        };
        if let Some(reg) = self.obs.lock().unwrap().as_ref() {
            reg.inc("surveiledge_node_verdicts_total", &[("site", site)], 1);
            reg.observe("surveiledge_node_latency_seconds", &[("site", site)], v.latency);
        }
    }

    /// Count an explicit overload shed (mirrored into the registry when
    /// one is attached).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if let Some(reg) = self.obs.lock().unwrap().as_ref() {
            reg.inc("surveiledge_node_shed_total", &[], 1);
        }
    }
}

/// Live-mode overload control for one edge: the same
/// [`DegradationLadder`] the DES engine runs, fed from the worker's
/// admitted-queue occupancy against a configured cap. `Subsample` lives
/// in the frame feeder, not here; this worker enforces the `EdgeLocal`
/// rung (through the shared classify stage) and the `Shed` rung.
pub struct LiveOverload {
    pub ladder: Mutex<DegradationLadder>,
    /// Queue occupancy = `NodeState::queue / queue_cap` (cap 0 = no
    /// pressure signal; the ladder then never escalates).
    pub queue_cap: usize,
}

impl LiveOverload {
    pub fn new(ladder: DegradationLadder, queue_cap: usize) -> LiveOverload {
        LiveOverload { ladder: Mutex::new(ladder), queue_cap }
    }
}

/// The per-edge classification worker (live mode).
pub struct EdgeWorker {
    pub state: Arc<NodeState>,
    pub scheme: Scheme,
    pub controller: Mutex<ThresholdController>,
    pub service: ServiceHandle,
    pub broker: Broker,
    pub db: ParamDb,
    pub metrics: Arc<RunMetrics>,
    pub query: ClassId,
    /// Slowdown multiplier (1.0 = host speed; the paper's Docker core
    /// limits become service-time multipliers here).
    pub slowdown: f64,
    /// Active multi-query set, if any: every edge verdict additionally
    /// fans out per-query threshold decisions on `query/<id>/results`.
    pub queries: Option<QuerySet>,
    /// Overload control, if configured (`None` = the pre-overload
    /// behavior, bit for bit).
    pub overload: Option<LiveOverload>,
}

impl EdgeWorker {
    /// Process one task fully. Returns the verdict if answered at the
    /// edge, `None` if the crop was uploaded for cloud re-classification
    /// — or explicitly shed by the degradation ladder's top rung
    /// ([`RunMetrics::shed`] distinguishes the two).
    pub fn classify(&self, task: Task, now_fn: &dyn Fn() -> f64) -> crate::Result<Option<Verdict>> {
        let t0 = now_fn();
        // Overload: refresh this edge's ladder from queue occupancy, and
        // at the top rung drop the task before spending inference on it.
        if let Some(ov) = &self.overload {
            let pressure = if ov.queue_cap > 0 {
                self.state.queue.load(Ordering::Relaxed) as f64 / ov.queue_cap as f64
            } else {
                0.0
            };
            let level = ov.ladder.lock().unwrap().observe(pressure, t0);
            if level >= LoadLevel::Shed {
                self.metrics.record_shed();
                return Ok(None);
            }
        }
        let probs = self.service.edge_infer(self.state.id.0, task.crop.data.clone())?;
        let confidence = probs.get(1).copied().unwrap_or(0.0);
        // Heterogeneity: pad the measured service time by the slowdown.
        let measured = now_fn() - t0;
        if self.slowdown > 1.0 {
            let pad = measured * (self.slowdown - 1.0);
            if pad > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(pad.min(0.5)));
            }
        }
        // The shared classify stage (`harness::pipeline::classify_stage`):
        // controller update (eqs. 8–9), the scheme's band decision, and
        // the cloud-liveness fallback — the exact code the DES engine runs
        // per task. This substrate answers the stage's questions through
        // [`LiveCtx`]; α/β replicate to the parameter DB afterwards.
        let outcome = {
            let mut ctl = self.controller.lock().unwrap();
            let outcome = classify_stage(
                &LiveCtx { worker: self, now: now_fn() },
                policy_for(self.scheme),
                &mut ctl,
                confidence,
            );
            self.db.put(ParamDb::key_alpha(), Value::F64(ctl.alpha));
            self.db.put(ParamDb::key_beta(), Value::F64(ctl.beta));
            outcome
        };
        // Feedback for tᵢ (eq. 17 fast path + lognormal window).
        self.state
            .estimator
            .lock()
            .unwrap()
            .observe((now_fn() - t0).max(1e-6));
        self.state.publish(&self.db);

        match outcome.action {
            EdgeAction::Verdict { positive } => {
                let v = verdict_from(
                    &task,
                    confidence,
                    positive,
                    Where::Edge(self.state.id),
                    now_fn(),
                    self.query,
                    None,
                );
                self.metrics.record_verdict(&v);
                self.broker.publish(
                    Message::new(format!("verdict/{}", self.state.id), encode_verdict(&v)),
                    QoS::AtMostOnce,
                );
                self.publish_query_results(&task, confidence, v.latency, now_fn());
                Ok(Some(v))
            }
            EdgeAction::Degrade { positive } => {
                // Cloud unreachable (stale heartbeat): answer locally with
                // a hard 0.5 split instead of stranding the crop on a dead
                // upload path.
                self.metrics.degraded.fetch_add(1, Ordering::Relaxed);
                let v = verdict_from(
                    &task,
                    confidence,
                    positive,
                    Where::Edge(self.state.id),
                    now_fn(),
                    self.query,
                    None,
                );
                self.metrics.record_verdict(&v);
                self.broker.publish(
                    Message::new(format!("verdict/{}", self.state.id), encode_verdict(&v)),
                    QoS::AtMostOnce,
                );
                self.publish_query_results(&task, confidence, v.latency, now_fn());
                Ok(Some(v))
            }
            EdgeAction::Upload => {
                self.metrics
                    .bandwidth
                    .lock()
                    .unwrap()
                    .add(&format!("{}->cloud", self.state.id), task.crop.wire_bytes());
                self.metrics.cloud_backlog.fetch_add(1, Ordering::Relaxed);
                let payload = encode_task(&task, confidence);
                self.broker
                    .publish(Message::new("task/cloud", payload), QoS::AtLeastOnce);
                Ok(None)
            }
        }
    }

    /// Work sharing on the live substrate: the one deployed CQ-specific
    /// CNN ran exactly once for this crop; every query covering the
    /// task's camera re-thresholds that shared confidence and streams its
    /// own decision on `query/<id>/results`. An edge-local verdict never
    /// paid a cloud upload, so per-query doubt resolves locally
    /// (`shared_cloud = false` in [`QuerySpec::decide`]).
    fn publish_query_results(&self, task: &Task, confidence: f32, latency: f64, now: f64) {
        let Some(qs) = &self.queries else { return };
        for (_, spec) in qs.active(task.camera, now) {
            let (positive, site) = spec.decide(confidence, false, false);
            qs.publish_result(&QueryVerdict {
                query: spec.id.clone(),
                task: task.id,
                t: now,
                positive,
                confidence,
                site,
                latency,
            });
        }
    }
}

/// The live substrate's answers to the shared classify stage: the eq. 8
/// congestion signal from atomics + the replicated parameter DB, and cloud
/// liveness from wall-clock heartbeats.
struct LiveCtx<'a> {
    worker: &'a EdgeWorker,
    now: f64,
}

impl PipelineCtx for LiveCtx<'_> {
    /// l_d·t_d for d = cloud: outstanding uploads x the cloud's advertised
    /// per-task latency (replicated via the parameter DB), plus the local
    /// wait.
    fn congestion_signal(&self) -> f64 {
        let w = self.worker;
        let backlog = w.metrics.cloud_backlog.load(Ordering::Relaxed) as f64;
        let t_cloud = w.db.get_f64(&ParamDb::key_t(0)).unwrap_or(0.001);
        let q_local = w.state.queue.load(Ordering::Relaxed) as f64;
        let t_local = w.state.estimator.lock().unwrap().estimate();
        backlog * t_cloud + q_local * t_local
    }

    fn cloud_alive(&self) -> bool {
        node_alive(&self.worker.db, 0, self.now)
    }

    fn query_set(&self) -> Option<&QuerySet> {
        self.worker.queries.as_ref()
    }

    /// The live ladder level: at `EdgeLocal` and above the shared stage
    /// answers doubtful crops locally instead of uploading — the same
    /// behavior the DES engine shows under queue pressure.
    fn overload_level(&self) -> LoadLevel {
        self.worker
            .overload
            .as_ref()
            .map_or(LoadLevel::Normal, |ov| ov.ladder.lock().unwrap().level())
    }
}

/// Compact wire encodings for bus traffic. Fixed little-endian layout
/// (no serde in the vendor set); covered by round-trip tests.
pub fn encode_task(task: &Task, confidence: f32) -> Vec<u8> {
    let mut out = Vec::with_capacity(44 + task.crop.data.len() * 4);
    out.extend_from_slice(&task.id.to_le_bytes());
    out.extend_from_slice(&task.camera.0.to_le_bytes());
    out.extend_from_slice(&task.frame_seq.to_le_bytes());
    out.extend_from_slice(&task.t_capture.to_le_bytes());
    out.extend_from_slice(&confidence.to_le_bytes());
    out.extend_from_slice(&(task.truth.map_or(u32::MAX, |c| c.index() as u32)).to_le_bytes());
    out.extend_from_slice(&(task.crop.h as u32).to_le_bytes());
    out.extend_from_slice(&(task.crop.w as u32).to_le_bytes());
    for v in &task.crop.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decoded upload: task + the edge's confidence.
pub struct UploadedTask {
    pub task: Task,
    pub edge_confidence: f32,
}

fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize) -> &'a [u8] {
    let s = &bytes[*off..*off + n];
    *off += n;
    s
}

pub fn decode_task(bytes: &[u8]) -> crate::Result<UploadedTask> {
    anyhow::ensure!(bytes.len() >= 44, "short task payload");
    let mut off = 0usize;
    let id = u64::from_le_bytes(take(bytes, &mut off, 8).try_into()?);
    let camera = u32::from_le_bytes(take(bytes, &mut off, 4).try_into()?);
    let frame_seq = u64::from_le_bytes(take(bytes, &mut off, 8).try_into()?);
    let t_capture = f64::from_le_bytes(take(bytes, &mut off, 8).try_into()?);
    let confidence = f32::from_le_bytes(take(bytes, &mut off, 4).try_into()?);
    let truth_raw = u32::from_le_bytes(take(bytes, &mut off, 4).try_into()?);
    let h = u32::from_le_bytes(take(bytes, &mut off, 4).try_into()?) as usize;
    let w = u32::from_le_bytes(take(bytes, &mut off, 4).try_into()?) as usize;
    anyhow::ensure!(bytes.len() == 44 + h * w * 3 * 4, "task payload size mismatch");
    let mut data = Vec::with_capacity(h * w * 3);
    for chunk in bytes[44..].chunks_exact(4) {
        data.push(f32::from_le_bytes(chunk.try_into()?));
    }
    Ok(UploadedTask {
        task: Task {
            id,
            camera: crate::types::CameraId(camera),
            frame_seq,
            t_capture,
            t_detected: t_capture,
            bbox: crate::types::BBox { y0: 0, x0: 0, y1: h, x1: w },
            crop: crate::types::Image { h, w, data },
            truth: if truth_raw == u32::MAX {
                None
            } else {
                ClassId::from_index(truth_raw as usize)
            },
        },
        edge_confidence: confidence,
    })
}

pub fn encode_verdict(v: &Verdict) -> Vec<u8> {
    let mut out = Vec::with_capacity(33);
    out.extend_from_slice(&v.task_id.to_le_bytes());
    out.extend_from_slice(&v.camera.0.to_le_bytes());
    out.extend_from_slice(&v.frame_seq.to_le_bytes());
    out.push(v.positive as u8);
    out.extend_from_slice(&v.confidence.to_le_bytes());
    out.extend_from_slice(&v.latency.to_le_bytes());
    out
}

/// The cloud-side worker: consumes `task/cloud`, classifies with the
/// high-accuracy CNN, publishes verdicts.
pub struct CloudWorker {
    pub state: Arc<NodeState>,
    pub service: ServiceHandle,
    pub broker: Broker,
    pub db: ParamDb,
    pub metrics: Arc<RunMetrics>,
    pub query: ClassId,
}

impl CloudWorker {
    pub fn classify(&self, up: UploadedTask, now_fn: &dyn Fn() -> f64) -> crate::Result<Verdict> {
        let t0 = now_fn();
        let probs = self.service.cloud_infer(up.task.crop.data.clone())?;
        let qidx = self.query.index();
        let positive = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i == qidx)
            .unwrap_or(false);
        self.state
            .estimator
            .lock()
            .unwrap()
            .observe((now_fn() - t0).max(1e-6));
        self.state.publish(&self.db);
        // The cloud CNN *is* the paper's ground truth: oracle == its answer.
        let v = verdict_from(
            &up.task,
            up.edge_confidence,
            positive,
            Where::Cloud,
            now_fn(),
            self.query,
            Some(positive),
        );
        self.metrics.record_verdict(&v);
        let backlog = &self.metrics.cloud_backlog;
        let _ = backlog.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| b.checked_sub(1));
        self.broker
            .publish(Message::new("verdict/cloud", encode_verdict(&v)), QoS::AtMostOnce);
        Ok(v)
    }
}

/// Build the allocator candidate list from the replicated parameter DB
/// (paper eq. 7 over α/β/tᵢ/Qᵢ state). Local node first (tie-break).
pub fn candidates_from_db(
    db: &ParamDb,
    local: NodeId,
    n_edges: u32,
    upload_penalty: f64,
) -> Vec<NodeLoad> {
    let mut ids: Vec<u32> = vec![local.0];
    for e in 1..=n_edges {
        if e != local.0 {
            ids.push(e);
        }
    }
    ids.push(0); // cloud last
    ids.into_iter()
        .map(|id| NodeLoad {
            node: NodeId(id),
            queue: db.get_u64(&ParamDb::key_q(id)).unwrap_or(0) as usize,
            t_infer: db.get_f64(&ParamDb::key_t(id)).unwrap_or(0.5),
            penalty: if id == 0 { upload_penalty } else { 0.0 },
        })
        .collect()
}

/// [`candidates_from_db`] restricted to live nodes: entries whose
/// heartbeat went stale are excluded (allocator failover). The local node
/// is always kept — it is the fallback when everything else looks dead,
/// and a node never considers itself failed.
pub fn live_candidates_from_db(
    db: &ParamDb,
    local: NodeId,
    n_edges: u32,
    upload_penalty: f64,
    now: f64,
) -> Vec<NodeLoad> {
    candidates_from_db(db, local, n_edges, upload_penalty)
        .into_iter()
        .filter(|l| l.node == local || node_alive(db, l.node.0, now))
        .collect()
}

/// Controller factory per scheme — delegates to the scheme's
/// [`SchemePolicy`](crate::harness::SchemePolicy) so both substrates agree
/// on controller construction by construction.
pub fn controller_for(scheme: Scheme, gamma1: f64, gamma2: f64, interval: f64) -> ThresholdController {
    policy_for(scheme).controller(gamma1, gamma2, interval)
}

/// Stop flag shared across node threads.
pub type StopFlag = Arc<AtomicBool>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::allocate;
    use crate::types::{BBox, CameraId, Image};

    fn demo_task(id: u64) -> Task {
        let mut crop = Image::new(4, 5);
        for (i, v) in crop.data.iter_mut().enumerate() {
            *v = (i % 7) as f32 / 7.0;
        }
        Task {
            id,
            camera: CameraId(3),
            frame_seq: 42,
            t_capture: 1.5,
            t_detected: 1.6,
            bbox: BBox { y0: 0, x0: 0, y1: 4, x1: 5 },
            crop,
            truth: Some(ClassId::Moped),
        }
    }

    #[test]
    fn task_wire_roundtrip() {
        let task = demo_task(9);
        let bytes = encode_task(&task, 0.625);
        let up = decode_task(&bytes).unwrap();
        assert_eq!(up.task.id, 9);
        assert_eq!(up.task.camera, CameraId(3));
        assert_eq!(up.task.frame_seq, 42);
        assert_eq!(up.task.t_capture, 1.5);
        assert_eq!(up.edge_confidence, 0.625);
        assert_eq!(up.task.truth, Some(ClassId::Moped));
        assert_eq!(up.task.crop.data, task.crop.data);
    }

    #[test]
    fn task_wire_roundtrip_no_truth() {
        let mut task = demo_task(1);
        task.truth = None;
        let up = decode_task(&encode_task(&task, 0.5)).unwrap();
        assert_eq!(up.task.truth, None);
    }

    #[test]
    fn decode_rejects_short_or_mismatched() {
        assert!(decode_task(&[0u8; 10]).is_err());
        let task = demo_task(2);
        let mut bytes = encode_task(&task, 0.5);
        bytes.truncate(bytes.len() - 4);
        assert!(decode_task(&bytes).is_err());
    }

    #[test]
    fn verdict_latency_measured_from_capture() {
        let task = demo_task(5);
        let v = verdict_from(&task, 0.9, true, Where::Cloud, 4.0, ClassId::Moped, Some(true));
        assert!((v.latency - 2.5).abs() < 1e-12);
        assert_eq!(v.truth_positive, Some(true));
    }

    #[test]
    fn run_metrics_aggregates() {
        let m = RunMetrics::default();
        let task = demo_task(6);
        let pos = verdict_from(&task, 0.9, true, Where::Edge(NodeId(1)), 2.0, ClassId::Moped, Some(true));
        let neg = verdict_from(&task, 0.2, false, Where::Cloud, 3.0, ClassId::Moped, Some(false));
        m.record_verdict(&pos);
        m.record_verdict(&neg);
        assert_eq!(m.vs_oracle.lock().unwrap().total(), 2);
        assert_eq!(m.latency.lock().unwrap().len(), 2);
        assert_eq!(m.answered_at_edge.load(Ordering::Relaxed), 1);
        assert_eq!(m.uploads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn candidates_order_and_penalty() {
        let db = ParamDb::new();
        db.put(&ParamDb::key_q(1), Value::U64(5));
        db.put(&ParamDb::key_t(1), Value::F64(0.3));
        db.put(&ParamDb::key_q(2), Value::U64(1));
        db.put(&ParamDb::key_t(2), Value::F64(0.4));
        db.put(&ParamDb::key_q(0), Value::U64(0));
        db.put(&ParamDb::key_t(0), Value::F64(0.05));
        let c = candidates_from_db(&db, NodeId(2), 2, 0.7);
        assert_eq!(c[0].node, NodeId(2), "local node must come first");
        assert_eq!(c.last().unwrap().node, NodeId::CLOUD);
        assert_eq!(c.last().unwrap().penalty, 0.7);
        // Costs: edge2 = 0.4, edge1 = 1.5, cloud = 0.7 -> edge2 wins.
        assert_eq!(allocate(&c), Some(NodeId(2)));
    }

    #[test]
    fn candidates_default_when_db_empty() {
        let db = ParamDb::new();
        let c = candidates_from_db(&db, NodeId(1), 3, 0.2);
        assert_eq!(c.len(), 4);
        assert!(c.iter().all(|l| l.queue == 0));
    }

    #[test]
    fn node_alive_tracks_heartbeat_staleness() {
        let db = ParamDb::new();
        assert!(node_alive(&db, 1, 100.0), "no heartbeat yet = presumed alive");
        let st = NodeState::new(NodeId(1), 0.4);
        st.heartbeat(&db, 10.0);
        assert!(node_alive(&db, 1, 12.0));
        assert!(!node_alive(&db, 1, 10.0 + crate::faults::HB_STALE_AFTER + 0.01));
        st.heartbeat(&db, 20.0);
        assert!(node_alive(&db, 1, 21.0), "a fresh beat revives the node");
    }

    #[test]
    fn live_candidates_exclude_stale_nodes_but_keep_local() {
        let db = ParamDb::new();
        let now = 30.0;
        // Edge 1 beat recently, edge 2 and the cloud went silent at t=10.
        db.put(&ParamDb::key_hb(1), Value::F64(now - 1.0));
        db.put(&ParamDb::key_hb(2), Value::F64(10.0));
        db.put(&ParamDb::key_hb(0), Value::F64(10.0));
        let c = live_candidates_from_db(&db, NodeId(1), 2, 0.2, now);
        let ids: Vec<u32> = c.iter().map(|l| l.node.0).collect();
        assert_eq!(ids, vec![1], "stale edge 2 and stale cloud are excluded");
        // From edge 2's own perspective it stays a candidate (local).
        let c2 = live_candidates_from_db(&db, NodeId(2), 2, 0.2, now);
        let ids2: Vec<u32> = c2.iter().map(|l| l.node.0).collect();
        assert_eq!(ids2, vec![2, 1]);
        // No heartbeats recorded at all: behaves exactly like
        // candidates_from_db (back-compat when heartbeating is off).
        let silent = ParamDb::new();
        assert_eq!(live_candidates_from_db(&silent, NodeId(1), 2, 0.2, now).len(), 3);
    }

    #[test]
    fn controller_for_schemes() {
        let fixed = controller_for(Scheme::SurveilEdgeFixed, 0.1, 0.25, 1.0);
        assert_eq!(fixed.alpha, 0.8);
        assert_eq!(fixed.beta, 0.1);
        let adaptive = controller_for(Scheme::SurveilEdge, 0.1, 0.25, 1.0);
        assert!(adaptive.alpha >= 0.5);
        assert!(adaptive.beta < adaptive.alpha);
    }

    #[test]
    fn run_metrics_count_explicit_sheds() {
        let m = RunMetrics::default();
        let reg = crate::obs::Registry::new();
        m.attach_registry(reg.clone());
        m.record_shed();
        m.record_shed();
        assert_eq!(m.shed.load(Ordering::Relaxed), 2);
        assert_eq!(reg.counter("surveiledge_node_shed_total", &[]), 2);
    }

    #[test]
    fn live_overload_ladder_reaches_shed_under_sustained_pressure() {
        use crate::overload::{LadderConfig, LoadLevel};
        let ov = LiveOverload::new(DegradationLadder::new(LadderConfig::default()), 4);
        // Saturated queue: pressure 1.0 escalates straight to the top rung.
        assert_eq!(ov.ladder.lock().unwrap().observe(1.0, 0.0), LoadLevel::Shed);
        // Cap 0 means no pressure signal — the ladder never escalates,
        // matching the "no [overload] block" inert default.
        let quiet = LiveOverload::new(DegradationLadder::new(LadderConfig::default()), 0);
        assert_eq!(quiet.ladder.lock().unwrap().observe(0.0, 0.0), LoadLevel::Normal);
    }

    #[test]
    fn node_state_publishes_to_db() {
        let db = ParamDb::new();
        let st = NodeState::new(NodeId(2), 0.4);
        st.queue.store(7, Ordering::Relaxed);
        st.publish(&db);
        assert_eq!(db.get_u64("q/2"), Some(7));
        assert!(db.get_f64("t/2").unwrap() > 0.0);
    }
}
