//! Experiment harness: discrete-event reproduction of the paper's
//! evaluation (Tables II–IV, Figs. 6–8).
//!
//! The harness replays a surveillance workload through the full pipeline
//! under each of the four schemes. Logical (simulated) time carries the
//! queueing dynamics — service times are calibrated to the paper's
//! hardware (edge CPU MobileNet, cloud P4 ResNet-152, shared uplink) — so
//! the experiments run the paper's multi-hour regime in seconds on this
//! one-core host. Compute itself has two modes:
//!
//! * `ComputeMode::Pjrt` (requires `--features pjrt`) — every
//!   classification is a *real* PJRT call on the AOT artifacts (real CNN
//!   confidences; logical service times).
//! * `ComputeMode::Synthetic` — confidences drawn from a calibrated
//!   distribution (for fast sweeps and benches without artifacts; the
//!   default build's only mode).
//!
//! Network model: each edge has a FIFO uplink of `uplink_mbps`; a crop's
//! wire size models the *native-resolution* crop the paper ships (our
//! 96×128 frames stand in for 1080p — an `HD_SCALE` area factor,
//! DESIGN.md §3), which is what makes cloud-only bandwidth-bound, as in
//! the paper.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::{Config, Scheme};
use crate::detect::{detect, DetectConfig};
use crate::estimator::LatencyEstimator;
use crate::faults::{backoff, FaultPlan, HB_INTERVAL, HB_STALE_AFTER, MAX_DISPATCH_ATTEMPTS};
use crate::metrics::{Confusion, FaultStats, LatencyRecorder, SchemeRow};
use crate::nodes::node_alive;
use crate::obs::{Registry, Report, SpanEvent, Stage};
use crate::paramdb::{ParamDb, Value};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, ModelRunner, MomentumSgd};
use crate::sched::{allocate, record_allocation, BandDecision, NodeLoad, ThresholdConfig, ThresholdController};
use crate::testkit::Rng;
use crate::trace::synth_confidence;
use crate::types::{ClassId, Image, NodeId};
use crate::video::standard_deployment;

/// Area factor mapping our synthetic frame resolution to the 1080p the
/// paper transmits (linear scale ~15x => area ~225x).
pub const HD_SCALE: u64 = 225;

/// Calibrated service-time constants (paper-era hardware, DESIGN.md §3).
#[derive(Clone, Copy, Debug)]
pub struct ServiceTimes {
    /// Edge CQ-CNN per-crop inference at speed 1.0 (i7 CPU, MobileNet).
    pub edge_infer: f64,
    /// Cloud high-accuracy CNN per-crop inference (P4 GPU, ResNet-152).
    pub cloud_infer: f64,
}

impl Default for ServiceTimes {
    fn default() -> ServiceTimes {
        ServiceTimes { edge_infer: 0.28, cloud_infer: 0.12 }
    }
}

/// Compute source for classifications.
pub enum ComputeMode {
    /// Real PJRT inference through the AOT bundle (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt(Box<PjrtCtx>),
    /// Calibrated synthetic confidences (no artifacts required).
    Synthetic {
        /// Edge CNN separability (higher = better CQ-CNN).
        sharpness: f64,
        /// Probability the edge CNN is *confidently wrong* (drawn as if
        /// the object were the other class) — models the calibration gap
        /// that gives the paper's edge-only its ~69% F2.
        edge_flip: f64,
        /// Probability the cloud oracle agrees with ground truth.
        oracle_acc: f64,
    },
}

impl ComputeMode {
    /// The calibrated synthetic mode every CLI/bench defaults to (matches
    /// the paper-era confidence calibration, DESIGN.md §3).
    pub fn synthetic_default() -> ComputeMode {
        ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
    }
}

/// Standard mode selection shared by the binary, benches and examples:
/// PJRT when requested (requires the `pjrt` feature and artifacts, with 30
/// fine-tune steps), the calibrated synthetic mode otherwise.
pub fn standard_mode(cfg: &Config, pjrt: bool) -> crate::Result<ComputeMode> {
    let _ = cfg; // only consulted on the PJRT path
    if pjrt {
        #[cfg(feature = "pjrt")]
        return Ok(ComputeMode::Pjrt(Box::new(PjrtCtx::prepare(cfg, 30)?)));
        #[cfg(not(feature = "pjrt"))]
        anyhow::bail!(
            "--pjrt / BENCH_PJRT=1 needs a build with the runtime bridge: \
             cargo build --release --features pjrt (and `make artifacts`)"
        );
    }
    Ok(ComputeMode::synthetic_default())
}

/// PJRT context: engine + fine-tuned edge model + cloud model.
#[cfg(feature = "pjrt")]
pub struct PjrtCtx {
    pub engine: Engine,
    pub edge_model: ModelRunner,
    pub cloud_model: ModelRunner,
}

#[cfg(feature = "pjrt")]
impl PjrtCtx {
    /// Build the context: load the bundle and run the online fine-tuning
    /// stage (head-group momentum-SGD on a renderer-generated
    /// context dataset) so the deployed edge model is the CQ-specific CNN.
    pub fn prepare(cfg: &Config, finetune_steps: usize) -> crate::Result<PjrtCtx> {
        let engine = Engine::new(std::path::Path::new(&cfg.artifacts))?;
        let mut params = engine.edge_pretrained()?;
        if finetune_steps > 0 {
            let trainer = engine.trainer()?;
            let n = params.len();
            let mask = MomentumSgd::head_only_mask(n, engine.manifest.edge_head_group);
            let mut opt = MomentumSgd::new(&engine.manifest.edge_params, 0.005, mask);
            let (pixels, labels) = finetune_corpus(cfg.query, 256, cfg.seed ^ 0xF1);
            let batch = trainer.batch;
            let px = trainer.img * trainer.img * 3;
            let mut rng = Rng::new(cfg.seed ^ 0x7A);
            let mut bpix = vec![0.0f32; batch * px];
            let mut blab = vec![0i32; batch];
            for _ in 0..finetune_steps {
                for j in 0..batch {
                    let k = rng.range_usize(0, labels.len());
                    bpix[j * px..(j + 1) * px].copy_from_slice(&pixels[k * px..(k + 1) * px]);
                    blab[j] = labels[k];
                }
                let out = trainer.grad_step(&params, &bpix, &blab)?;
                opt.step(&mut params, &out.grads);
            }
        }
        let edge_model = engine.edge_model(1, &params)?;
        let cloud_model = engine.cloud_model(1, &engine.cloud_trained()?)?;
        Ok(PjrtCtx { engine, edge_model, cloud_model })
    }
}

/// Renderer-generated binary fine-tune corpus (query vs rest), balanced.
pub fn finetune_corpus(query: ClassId, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    use crate::video::sprite::{render_sprite, SpriteParams};
    let mut rng = Rng::new(seed);
    let mut pixels = Vec::with_capacity(n * 32 * 32 * 3);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let positive = i % 2 == 0;
        let cls = if positive {
            query
        } else {
            loop {
                let c = ClassId::from_index(rng.range_usize(0, 8)).unwrap();
                if c != query {
                    break c;
                }
            }
        };
        let sprite = render_sprite(&SpriteParams {
            cls,
            size: rng.range_usize(14, 31),
            base: [rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95)],
            accent: [rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95)],
            bg: [0.42 + rng.range_f32(-0.08, 0.08), 0.45 + rng.range_f32(-0.08, 0.08), 0.42 + rng.range_f32(-0.08, 0.08)],
            rot: rng.range_f32(-0.35, 0.35),
            jx: rng.range_f32(-0.12, 0.12),
            jy: rng.range_f32(-0.12, 0.12),
            noise: rng.range_f32(0.02, 0.14),
            seed: rng.next_u32(),
        });
        pixels.extend_from_slice(&sprite.resize(32, 32).data);
        labels.push(positive as i32);
    }
    (pixels, labels)
}

/// One task flowing through the DES.
#[derive(Clone)]
struct SimTask {
    id: u64,
    t_capture: f64,
    home_edge: u32,
    /// When the task last entered a queue (node or uplink) — feeds the
    /// queue/uplink stage spans.
    t_enqueue: f64,
    /// Crop pixels (PJRT mode) — empty in synthetic mode.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    crop: Vec<f32>,
    wire_bytes: u64,
    truth_positive: Option<bool>,
    /// Precomputed oracle answer (what the cloud CNN says).
    oracle_positive: bool,
    /// Precomputed edge confidence (synthetic mode) or None (PJRT).
    synth_confidence: Option<f32>,
    /// Delivery attempts so far (fault runs: drop / no-ack retries).
    attempt: u32,
    /// Set once an edge classified it doubtful — from then on its
    /// destination is pinned to the cloud re-check path.
    doubtful: bool,
}

/// DES events.
enum Event {
    /// Sample all cameras of all edges at this tick.
    Sample,
    /// A node finished its current classification.
    NodeFinish { node: u32 },
    /// An uplink finished its current transfer.
    UplinkFinish { edge: u32 },
    /// A failed edge comes back and resumes its queue.
    NodeResume { node: u32 },
    /// Heartbeat tick: every live node publishes `hb/<id>` (fault runs
    /// only — fault-free runs never schedule this).
    Heartbeat,
    /// Scripted fault-plan transitions.
    FaultCrash { node: u32 },
    FaultRecover { node: u32 },
    /// Stale-heartbeat detection point after a crash: sweep the dead
    /// node's stranded queue back through the allocator.
    Failover { node: u32, crash_from: f64 },
    /// Ack-timeout backoff expired: re-dispatch a task whose delivery
    /// failed.
    Redispatch { task: SimTask },
}

struct HeapKey(f64, u64);

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0 && self.1 == other.1
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.1.cmp(&other.1))
    }
}

/// Per-node (edge or cloud) queue state.
struct NodeSim {
    queue: VecDeque<SimTask>,
    busy: bool,
    estimator: LatencyEstimator,
    speed: f64,
    /// Pending NodeFinish event id — cancelled when the node crashes.
    finish_ev: Option<u64>,
}

/// Per-edge uplink state.
struct Uplink {
    queue: VecDeque<SimTask>,
    busy: bool,
    /// Bytes waiting (including the in-flight transfer) — feeds the
    /// controller's congestion signal and the allocator's cloud penalty.
    queued_bytes: u64,
}

/// Result of one scheme run.
pub struct SchemeResult {
    pub row: SchemeRow,
    pub latency: LatencyRecorder,
    /// (verdict time, latency, home edge) triples — Figs. 6–8 (b)-(d).
    pub per_frame: Vec<(f64, f64, u32)>,
    pub vs_oracle: Confusion,
    pub vs_truth: Confusion,
    pub uploads: u64,
    pub tasks: u64,
    /// Mean doubtful-band width over the run (ablation diagnostics).
    pub mean_band_width: f64,
    /// Recovery metrics under fault injection (all-zero without a plan).
    pub faults: FaultStats,
}

impl SchemeResult {
    /// Collapse into the one stable [`Report`] schema every consumer
    /// (CLI, benches, integration tests, EXPERIMENTS.md recipes) reads
    /// metrics through: kind `scheme_run`, named after the scheme.
    pub fn report(&self) -> Report {
        let mut r = Report::new("scheme_run", &self.row.scheme);
        r.push("accuracy_f2", self.row.accuracy);
        r.push("avg_latency_s", self.row.avg_latency);
        r.push("p50_latency_s", self.latency.percentile(0.5));
        r.push("p99_latency_s", self.latency.percentile(0.99));
        r.push("bandwidth_mb", self.row.bandwidth_mb);
        r.push("tasks", self.tasks as f64);
        r.push("uploads", self.uploads as f64);
        r.push("mean_band_width", self.mean_band_width);
        self.faults.fill_report(&mut r);
        r
    }
}

/// Fault injection: an edge node goes dark for a time window. Tasks that
/// would run there must be re-routed (SurveilEdge) or stall until
/// recovery (schemes without an allocator) — an extension experiment
/// beyond the paper's evaluation (DESIGN.md §8).
#[derive(Clone, Copy, Debug)]
pub struct EdgeOutage {
    pub edge: u32,
    pub from: f64,
    pub until: f64,
}

impl EdgeOutage {
    pub fn covers(&self, t: f64, edge: u32) -> bool {
        edge == self.edge && t >= self.from && t < self.until
    }
}

/// The scheme runner.
pub struct Harness {
    pub cfg: Config,
    pub times: ServiceTimes,
    pub mode: ComputeMode,
    /// Optional fault injection (legacy single-window outage).
    pub outage: Option<EdgeOutage>,
    /// Scripted fault plan (crashes, drops, delays, slowdowns) — defaults
    /// to `cfg.faults`; `FaultPlan::none()` leaves the run fault-free.
    pub plan: FaultPlan,
    /// Observability sink: per-task stage spans + counters/gauges/
    /// histograms accumulate here when attached (`builder(..).observe(..)`).
    pub obs: Option<Registry>,
}

/// Builder for [`Harness`] — replaces the `Harness::new` +
/// `with_outage`/`with_plan` ad-hoc chaining:
///
/// ```ignore
/// let mut h = Harness::builder(cfg)
///     .mode(ComputeMode::synthetic_default())
///     .plan(plan)
///     .observe(registry)
///     .build();
/// ```
pub struct HarnessBuilder {
    cfg: Config,
    times: ServiceTimes,
    mode: Option<ComputeMode>,
    outage: Option<EdgeOutage>,
    plan: Option<FaultPlan>,
    obs: Option<Registry>,
}

impl HarnessBuilder {
    /// Compute source (defaults to [`ComputeMode::synthetic_default`]).
    pub fn mode(mut self, mode: ComputeMode) -> HarnessBuilder {
        self.mode = Some(mode);
        self
    }

    /// Override the calibrated service-time constants.
    pub fn times(mut self, times: ServiceTimes) -> HarnessBuilder {
        self.times = times;
        self
    }

    /// Legacy single-window edge outage.
    pub fn outage(mut self, outage: EdgeOutage) -> HarnessBuilder {
        self.outage = Some(outage);
        self
    }

    /// Override the fault schedule (defaults to the config's `[faults]`).
    pub fn plan(mut self, plan: FaultPlan) -> HarnessBuilder {
        self.plan = Some(plan);
        self
    }

    /// Attach a metric registry; the run records spans and metrics into it.
    pub fn observe(mut self, reg: Registry) -> HarnessBuilder {
        self.obs = Some(reg);
        self
    }

    pub fn build(self) -> Harness {
        let HarnessBuilder { cfg, times, mode, outage, plan, obs } = self;
        let plan = plan.unwrap_or_else(|| cfg.faults.clone());
        let mode = mode.unwrap_or_else(ComputeMode::synthetic_default);
        Harness { cfg, times, mode, outage, plan, obs }
    }
}

impl Harness {
    /// Start building a harness for `cfg` (see [`HarnessBuilder`]).
    pub fn builder(cfg: Config) -> HarnessBuilder {
        HarnessBuilder {
            cfg,
            times: ServiceTimes::default(),
            mode: None,
            outage: None,
            plan: None,
            obs: None,
        }
    }

    #[deprecated(since = "0.7.0", note = "use Harness::builder(cfg).mode(mode).build()")]
    pub fn new(cfg: Config, mode: ComputeMode) -> Harness {
        Harness::builder(cfg).mode(mode).build()
    }

    #[deprecated(since = "0.7.0", note = "use Harness::builder(..).outage(..)")]
    pub fn with_outage(mut self, outage: EdgeOutage) -> Harness {
        self.outage = Some(outage);
        self
    }

    /// Override the fault schedule (defaults to the config's `[faults]`).
    #[deprecated(since = "0.7.0", note = "use Harness::builder(..).plan(..)")]
    pub fn with_plan(mut self, plan: FaultPlan) -> Harness {
        self.plan = plan;
        self
    }

    /// Record one stage span (no-op without an attached registry): the
    /// per-scheme/per-stage latency histogram plus the timeline event.
    fn span(&self, scheme: Scheme, t: f64, task: u64, stage: Stage, node: u32, dur: f64, detail: &str) {
        if let Some(reg) = &self.obs {
            let dur = if dur.is_finite() { dur.max(0.0) } else { 0.0 };
            reg.observe(
                "surveiledge_stage_seconds",
                &[("scheme", scheme.name()), ("stage", stage.as_str())],
                dur,
            );
            reg.span(SpanEvent {
                t,
                task,
                stage,
                node,
                dur,
                scheme: scheme.name().to_string(),
                detail: detail.to_string(),
            });
        }
    }

    /// Run one scheme over the configured scenario.
    pub fn run(&mut self, scheme: Scheme) -> crate::Result<SchemeResult> {
        let cfg = self.cfg.clone();
        let n_edges = cfg.edges.len() as u32;
        let (frame_h, frame_w) = match &self.mode {
            #[cfg(feature = "pjrt")]
            ComputeMode::Pjrt(ctx) => (ctx.engine.manifest.frame_h, ctx.engine.manifest.frame_w),
            ComputeMode::Synthetic { .. } => (cfg.frame_h, cfg.frame_w),
        };

        // Cameras, assigned to edges in blocks.
        let mut cameras = standard_deployment(cfg.total_cameras() as usize, frame_h, frame_w, cfg.seed);
        let mut cam_edge: Vec<u32> = Vec::new();
        for (ei, e) in cfg.edges.iter().enumerate() {
            for _ in 0..e.cameras {
                cam_edge.push(ei as u32 + 1);
            }
        }

        // Node 0 = cloud; 1..=n = edges.
        let mut nodes: Vec<NodeSim> = Vec::new();
        nodes.push(NodeSim {
            queue: VecDeque::new(),
            busy: false,
            estimator: LatencyEstimator::new(self.times.cloud_infer),
            speed: cfg.cloud_speed,
            finish_ev: None,
        });
        for e in &cfg.edges {
            nodes.push(NodeSim {
                queue: VecDeque::new(),
                busy: false,
                estimator: LatencyEstimator::new(self.times.edge_infer / e.speed),
                speed: e.speed,
                finish_ev: None,
            });
        }
        let uplinks: Vec<Uplink> = (0..n_edges)
            .map(|_| Uplink { queue: VecDeque::new(), busy: false, queued_bytes: 0 })
            .collect();
        let mut controllers: Vec<ThresholdController> = (0..n_edges)
            .map(|_| match scheme {
                Scheme::SurveilEdgeFixed => ThresholdController::fixed(),
                _ => ThresholdController::new(
                    0.8,
                    ThresholdConfig { gamma1: cfg.gamma1, gamma2: cfg.gamma2, interval: cfg.interval },
                ),
            })
            .collect();

        // Detection state per camera: previous two sampled frames.
        let mut prev_frames: Vec<Option<(Image, Image)>> = vec![None; cameras.len()];
        let detect_cfg = DetectConfig::default();
        let uplink_bps = cfg.uplink_mbps * 1_000_000.0 / 8.0;

        let mut des = Des {
            nodes,
            uplinks,
            heap: BinaryHeap::new(),
            events: std::collections::HashMap::new(),
            seq: 0,
            cloud_bytes: 0,
            fstats: FaultStats::default(),
            times: self.times,
            uplink_bps,
            fx: FaultCtx { plan: self.plan.clone(), outage: self.outage },
        };
        des.schedule(cfg.interval, Event::Sample);
        // Heartbeats + scripted crash transitions only exist under a
        // non-empty plan, so fault-free runs replay the exact event
        // sequence they always had.
        let faulty = !des.fx.plan.is_empty();
        let db = ParamDb::new();
        if let Some(reg) = &self.obs {
            // Heartbeat puts flow through the paramdb counter wiring;
            // the fault plan's shape lands as gauges so an export is
            // self-describing.
            db.attach_registry(reg.clone());
            if faulty {
                self.plan.export_into(reg, &[("scheme", scheme.name())]);
            }
        }
        // Drain horizon: keep serving queued tasks after the last sample.
        let drain_until = cfg.duration + 60.0;
        if faulty {
            des.schedule(0.0, Event::Heartbeat);
            for c in des.fx.plan.crashes.clone() {
                if c.until > c.from {
                    des.schedule(c.from, Event::FaultCrash { node: c.node });
                    des.schedule(c.until, Event::FaultRecover { node: c.node });
                    if scheme == Scheme::SurveilEdge {
                        des.schedule(
                            c.from + HB_STALE_AFTER,
                            Event::Failover { node: c.node, crash_from: c.from },
                        );
                    }
                }
            }
        }

        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut next_task_id = 0u64;
        let mut result = SchemeResult {
            row: SchemeRow {
                scheme: scheme.name().to_string(),
                accuracy: 0.0,
                avg_latency: 0.0,
                bandwidth_mb: 0.0,
            },
            latency: LatencyRecorder::new(),
            per_frame: Vec::new(),
            vs_oracle: Confusion::default(),
            vs_truth: Confusion::default(),
            uploads: 0,
            tasks: 0,
            mean_band_width: 0.0,
            faults: FaultStats::default(),
        };
        let mut band_width_acc = 0.0f64;
        let mut band_width_n = 0u64;

        while let Some(Reverse((HeapKey(t, id), _))) = des.heap.pop() {
            if t > drain_until {
                break;
            }
            // A missing slot is a cancelled event (a crash cancels the
            // victim's in-flight completion).
            let Some(ev) = des.events.remove(&id) else { continue };
            match ev {
                Event::Sample => {
                    if t + cfg.interval <= cfg.duration {
                        des.schedule(t + cfg.interval, Event::Sample);
                    }
                    // Detect on every camera at this tick.
                    for ci in 0..cameras.len() {
                        let frame = cameras[ci].frame_at(t);
                        let truth = cameras[ci].truth_at(t);
                        let Some((f_prev2, f_prev)) = prev_frames[ci].take() else {
                            prev_frames[ci] = Some((frame.image.clone(), frame.image));
                            continue;
                        };
                        let dets = detect(&f_prev2, &f_prev, &frame.image, &detect_cfg);
                        for det in dets {
                            let bb = det.bbox.expand(detect_cfg.margin, frame_h, frame_w);
                            let crop = f_prev
                                .crop(bb.y0, bb.x0, bb.y1, bb.x1)
                                .resize(detect_cfg.crop_size, detect_cfg.crop_size);
                            // Ground truth by best-IoU match.
                            let truth_cls = truth
                                .iter()
                                .map(|(c, tb)| (*c, det.bbox.iou(tb)))
                                .filter(|(_, iou)| *iou > 0.2)
                                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                                .map(|(c, _)| c);
                            let (oracle_positive, synth_confidence) =
                                self.judge(&crop, truth_cls, &mut rng)?;
                            let task = SimTask {
                                id: next_task_id,
                                t_capture: t - cfg.interval, // crop comes from the middle frame
                                home_edge: cam_edge[ci],
                                crop: match &self.mode {
                                    #[cfg(feature = "pjrt")]
                                    ComputeMode::Pjrt(_) => crop.data,
                                    ComputeMode::Synthetic { .. } => Vec::new(),
                                },
                                wire_bytes: (bb.area() as u64) * 3 * HD_SCALE,
                                truth_positive: truth_cls.map(|c| c == cfg.query),
                                oracle_positive,
                                synth_confidence,
                                attempt: 0,
                                doubtful: false,
                                t_enqueue: t,
                            };
                            next_task_id += 1;
                            result.tasks += 1;
                            // Detection span: frame-diff ran on the middle
                            // frame; the crop surfaces one interval later.
                            self.span(scheme, t, task.id, Stage::Detect, task.home_edge, t - task.t_capture, "");
                            // Route (eq. 7 or the scheme's fixed policy).
                            let dest =
                                self.route(scheme, task.home_edge, &des.nodes, &des.uplinks, &cfg, t, &db);
                            self.dispatch(scheme, task, dest, t, &mut des, &db, &mut result)?;
                        }
                        prev_frames[ci] = Some((f_prev, frame.image));
                    }
                }
                Event::NodeFinish { node } => {
                    let n = node as usize;
                    des.nodes[n].finish_ev = None;
                    let mut task = des.nodes[n].queue.pop_front().expect("finish without task");
                    des.nodes[n].busy = false;
                    let service =
                        service_time(node, &des.nodes[n], &self.times) * des.fx.plan.slowdown(node, t);
                    des.nodes[n].estimator.observe(service);
                    // Queue wait = time between entering this node's FIFO
                    // and service start (clamped: the slowdown factor can
                    // differ between scheduling and completion).
                    let qwait = (t - service - task.t_enqueue).max(0.0);
                    self.span(scheme, t - service, task.id, Stage::Queue, node, qwait, "");
                    let infer_stage = if node == 0 { Stage::CloudInfer } else { Stage::EdgeInfer };
                    self.span(scheme, t, task.id, infer_stage, node, service, "");
                    if node == 0 {
                        // Cloud verdict: the oracle's answer, by definition.
                        let latency = (t - task.t_capture) + cfg.rtt / 2.0;
                        self.finish(
                            &mut result,
                            scheme,
                            task.id,
                            task.oracle_positive,
                            task.oracle_positive,
                            task.truth_positive,
                            latency,
                            t,
                            task.home_edge,
                            "cloud",
                        );
                    } else {
                        // Edge classify -> band decision.
                        let conf = self.edge_confidence(&task)?;
                        let e = (node - 1) as usize;
                        {
                            // Controller signal (eq. 8's l_d·t_d): the
                            // expected latency of the *re-classification
                            // path* a doubtful image would take — uplink
                            // backlog + cloud queue — plus the local edge
                            // wait. When uploads congest the uplink, the
                            // band narrows; with headroom it widens.
                            // Band width only changes the *upload* volume,
                            // so the eq. 8 signal tracks the doubtful path:
                            // uplink backlog + cloud queue + rtt. (Edge
                            // queueing is the allocator's job, eq. 7.)
                            let signal = des.uplinks[e].queued_bytes as f64 / uplink_bps
                                + (des.nodes[0].queue.len() + des.nodes[0].busy as usize) as f64
                                    * des.nodes[0].estimator.estimate()
                                + cfg.rtt;
                            // update() multiplies queue*t; feed the signal
                            // as (1, signal) to keep the eq. 8 form.
                            controllers[e].update(1, signal);
                            band_width_acc += controllers[e].band_width();
                            band_width_n += 1;
                        }
                        let decision = match scheme {
                            Scheme::EdgeOnly => {
                                if conf >= 0.5 {
                                    BandDecision::Positive
                                } else {
                                    BandDecision::Negative
                                }
                            }
                            _ => controllers[e].decide(conf),
                        };
                        let band = match decision {
                            BandDecision::Positive => "positive",
                            BandDecision::Negative => "negative",
                            BandDecision::Doubtful => "doubtful",
                        };
                        self.span(scheme, t, task.id, Stage::ThresholdDecide, node, 0.0, band);
                        match decision {
                            BandDecision::Positive | BandDecision::Negative => {
                                self.finish(
                                    &mut result,
                                    scheme,
                                    task.id,
                                    decision == BandDecision::Positive,
                                    task.oracle_positive,
                                    task.truth_positive,
                                    t - task.t_capture,
                                    t,
                                    task.home_edge,
                                    "edge",
                                );
                            }
                            BandDecision::Doubtful => {
                                if faulty && !node_alive(&db, 0, t) {
                                    // Graceful degradation: the cloud's
                                    // heartbeat is stale, so answer with
                                    // the edge confidence rather than
                                    // queue into a dead path.
                                    self.degrade_finish(scheme, task, t, &mut des, &mut result)?;
                                } else {
                                    result.uploads += 1;
                                    task.doubtful = true;
                                    let e = (task.home_edge - 1) as usize;
                                    des.push_uplink(e, task, t);
                                }
                            }
                        }
                    }
                    // Start the next queued task, if any.
                    des.start_if_idle(n, t);
                }
                Event::NodeResume { node } => {
                    let n = node as usize;
                    des.nodes[n].busy = false;
                    des.start_if_idle(n, t);
                }
                Event::UplinkFinish { edge } => {
                    let e = edge as usize;
                    let task =
                        des.uplinks[e].queue.pop_front().expect("uplink finish without task");
                    des.uplinks[e].queued_bytes =
                        des.uplinks[e].queued_bytes.saturating_sub(task.wire_bytes);
                    des.uplinks[e].busy = false;
                    des.kick_uplink(e, t);
                    // Uplink span covers queue wait + the wire transfer.
                    self.span(scheme, t, task.id, Stage::Uplink, edge + 1, t - task.t_enqueue, "");
                    if des.fx.plan.drops(task.id, task.attempt) || des.fx.plan.is_down(0, t) {
                        // Lost in transit, or the cloud is down: no ack
                        // arrives before the timeout.
                        self.retry_or_degrade(scheme, task, t, &mut des, &db, &mut result)?;
                    } else {
                        // Deliver to the cloud queue after half an RTT
                        // (+ any injected one-way delay).
                        let arrival = t + cfg.rtt / 2.0 + des.fx.plan.delay_of(task.id);
                        des.enqueue_node(0, task, arrival);
                    }
                }
                Event::Heartbeat => {
                    for n in 0..des.nodes.len() as u32 {
                        if !des.fx.plan.is_down(n, t) {
                            db.put(&ParamDb::key_hb(n), Value::F64(t));
                        }
                    }
                    if t + HB_INTERVAL <= drain_until {
                        des.schedule(t + HB_INTERVAL, Event::Heartbeat);
                    }
                }
                Event::FaultCrash { node } => {
                    // The in-flight task (if any) is lost mid-service:
                    // cancel its completion. The task itself stays at the
                    // queue front for the failover sweep / restart.
                    let n = node as usize;
                    if let Some(ev_id) = des.nodes[n].finish_ev.take() {
                        des.events.remove(&ev_id);
                        des.nodes[n].busy = false;
                    }
                }
                Event::FaultRecover { node } => {
                    des.start_if_idle(node as usize, t);
                }
                Event::Failover { node, crash_from } => {
                    // Stale-heartbeat detection point: if the node is
                    // still down, re-queue its stranded tasks through the
                    // allocator (which now excludes it).
                    if des.fx.plan.is_down(node, t) {
                        let stranded: Vec<SimTask> =
                            des.nodes[node as usize].queue.drain(..).collect();
                        if !stranded.is_empty() && des.fstats.time_to_reroute == 0.0 {
                            des.fstats.time_to_reroute = t - crash_from;
                        }
                        for task in stranded {
                            des.fstats.rerouted += 1;
                            self.span(scheme, t, task.id, Stage::Reroute, node, 0.0, "");
                            let dest = self
                                .route(scheme, task.home_edge, &des.nodes, &des.uplinks, &cfg, t, &db);
                            self.dispatch(scheme, task, dest, t, &mut des, &db, &mut result)?;
                        }
                    }
                }
                Event::Redispatch { task } => {
                    if task.doubtful {
                        if !node_alive(&db, 0, t) {
                            // Still no cloud: answer locally instead of
                            // re-uploading into a dead path.
                            self.degrade_finish(scheme, task, t, &mut des, &mut result)?;
                        } else {
                            let e = (task.home_edge - 1) as usize;
                            des.push_uplink(e, task, t);
                        }
                    } else {
                        let dest =
                            self.route(scheme, task.home_edge, &des.nodes, &des.uplinks, &cfg, t, &db);
                        self.dispatch(scheme, task, dest, t, &mut des, &db, &mut result)?;
                    }
                }
            }
        }

        let f2 = result.vs_oracle.f2();
        result.row.accuracy = f2;
        result.row.avg_latency = result.latency.mean();
        result.row.bandwidth_mb = des.cloud_bytes as f64 / (1024.0 * 1024.0);
        result.mean_band_width = if band_width_n > 0 {
            band_width_acc / band_width_n as f64
        } else {
            0.0
        };
        result.faults = des.fstats;
        result.faults.lost = result.tasks.saturating_sub(result.latency.len() as u64);
        if let Some(reg) = &self.obs {
            let sl = [("scheme", scheme.name())];
            reg.inc("surveiledge_harness_tasks_total", &sl, result.tasks);
            reg.inc("surveiledge_harness_uploads_total", &sl, result.uploads);
            reg.inc("surveiledge_harness_uplink_bytes_total", &sl, des.cloud_bytes);
            reg.gauge_set("surveiledge_harness_accuracy_f2", &sl, result.row.accuracy);
            reg.gauge_set("surveiledge_harness_avg_latency_seconds", &sl, result.row.avg_latency);
            reg.gauge_set("surveiledge_harness_bandwidth_mb", &sl, result.row.bandwidth_mb);
            reg.gauge_set("surveiledge_harness_mean_band_width", &sl, result.mean_band_width);
            reg.inc("surveiledge_faults_retried_total", &sl, result.faults.retried);
            reg.inc("surveiledge_faults_rerouted_total", &sl, result.faults.rerouted);
            reg.inc("surveiledge_faults_degraded_total", &sl, result.faults.degraded);
            reg.inc("surveiledge_faults_lost_total", &sl, result.faults.lost);
            reg.gauge_set(
                "surveiledge_faults_time_to_reroute_seconds",
                &sl,
                result.faults.time_to_reroute,
            );
        }
        Ok(result)
    }

    /// Send `task` toward `dest` (as chosen by [`Harness::route`]). Under
    /// a fault plan a remote hop can fail — a dropped message or a dead
    /// destination goes to the retry path instead of a queue.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        scheme: Scheme,
        task: SimTask,
        dest: NodeId,
        t: f64,
        des: &mut Des,
        db: &ParamDb,
        result: &mut SchemeResult,
    ) -> crate::Result<()> {
        let home = task.home_edge;
        if dest.is_cloud() {
            // Uplink transfer; transit faults apply at delivery time.
            des.push_uplink((home - 1) as usize, task, t);
        } else if dest.0 != home
            && (des.fx.plan.drops(task.id, task.attempt) || des.fx.plan.is_down(dest.0, t))
        {
            // Edge-to-edge hop lost (or the peer just died): no ack.
            self.retry_or_degrade(scheme, task, t, des, db, result)?;
        } else {
            let delay = if dest.0 != home { des.fx.plan.delay_of(task.id) } else { 0.0 };
            des.enqueue_node(dest.0 as usize, task, t + delay);
        }
        Ok(())
    }

    /// A delivery failed: count the retry, back off exponentially, and
    /// re-dispatch — or give up gracefully once the attempt budget is
    /// spent or the cloud is known dead.
    fn retry_or_degrade(
        &mut self,
        scheme: Scheme,
        mut task: SimTask,
        t: f64,
        des: &mut Des,
        db: &ParamDb,
        result: &mut SchemeResult,
    ) -> crate::Result<()> {
        des.fstats.retried += 1;
        self.span(scheme, t, task.id, Stage::Retry, task.home_edge, 0.0, "");
        let attempt = task.attempt;
        task.attempt += 1;
        // Cloud-only has no edge fallback: it keeps retrying (bounded
        // backoff) until the cloud answers.
        if scheme != Scheme::CloudOnly {
            let cloud_dead = task.doubtful && !node_alive(db, 0, t);
            if cloud_dead || task.attempt >= MAX_DISPATCH_ATTEMPTS {
                if task.doubtful {
                    // §IV-D's latency/accuracy trade at its limit: an
                    // edge verdict now beats a cloud verdict never.
                    return self.degrade_finish(scheme, task, t, des, result);
                }
                // Unclassified task: fall back to local processing.
                let home = task.home_edge as usize;
                des.enqueue_node(home, task, t);
                return Ok(());
            }
        }
        des.schedule(t + backoff(attempt), Event::Redispatch { task });
        Ok(())
    }

    /// Edge-local verdict without the cloud re-check (graceful
    /// degradation when the cloud path is unavailable).
    fn degrade_finish(
        &mut self,
        scheme: Scheme,
        task: SimTask,
        t: f64,
        des: &mut Des,
        result: &mut SchemeResult,
    ) -> crate::Result<()> {
        des.fstats.degraded += 1;
        self.span(scheme, t, task.id, Stage::Degrade, task.home_edge, 0.0, "");
        let conf = self.edge_confidence(&task)?;
        self.finish(
            result,
            scheme,
            task.id,
            conf >= 0.5,
            task.oracle_positive,
            task.truth_positive,
            t - task.t_capture,
            t,
            task.home_edge,
            "degraded",
        );
        Ok(())
    }

    /// Routing policy per scheme.
    #[allow(clippy::too_many_arguments)]
    fn route(
        &self,
        scheme: Scheme,
        home: u32,
        nodes: &[NodeSim],
        uplinks: &[Uplink],
        cfg: &Config,
        t: f64,
        db: &ParamDb,
    ) -> NodeId {
        match scheme {
            Scheme::CloudOnly => NodeId::CLOUD,
            Scheme::EdgeOnly | Scheme::SurveilEdgeFixed => NodeId(home),
            Scheme::SurveilEdge => {
                // eq. 7 over {home edge first, other edges, cloud}; edges
                // under an injected outage or with a stale heartbeat are
                // not candidates (failover). Without heartbeats (fault-free
                // runs) `node_alive` is vacuously true.
                let dead = |e: u32| {
                    self.outage.map_or(false, |o| o.covers(t, e)) || !node_alive(db, e, t)
                };
                let mut cands: Vec<NodeLoad> = Vec::with_capacity(nodes.len());
                if !dead(home) {
                    cands.push(node_load(home, &nodes[home as usize], 0.0));
                }
                for i in 1..nodes.len() as u32 {
                    if i != home && !dead(i) {
                        cands.push(node_load(i, &nodes[i as usize], 0.0));
                    }
                }
                // Cloud penalty: rtt + typical crop transfer + current
                // uplink backlog on this edge's link.
                let backlog = uplinks[(home - 1) as usize].queued_bytes as f64;
                let upload = cfg.rtt
                    + (backlog + 24.0 * 24.0 * 3.0 * HD_SCALE as f64)
                        / (cfg.uplink_mbps * 125_000.0);
                if node_alive(db, 0, t) {
                    cands.push(node_load(0, &nodes[0], upload));
                }
                let dest = allocate(&cands).unwrap_or(NodeId(home));
                if let Some(reg) = &self.obs {
                    record_allocation(reg, scheme.name(), dest, &cands);
                }
                dest
            }
        }
    }

    /// Oracle answer + synthetic confidence for a new task.
    fn judge(
        &mut self,
        crop: &Image,
        truth: Option<ClassId>,
        rng: &mut Rng,
    ) -> crate::Result<(bool, Option<f32>)> {
        let query = self.cfg.query;
        let _ = crop; // only the PJRT arm consumes pixels
        match &mut self.mode {
            #[cfg(feature = "pjrt")]
            ComputeMode::Pjrt(ctx) => {
                let probs = ctx.cloud_model.infer(&crop.data)?;
                let best = probs[0]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(usize::MAX);
                Ok((best == query.index(), None))
            }
            ComputeMode::Synthetic { sharpness, edge_flip, oracle_acc } => {
                let truth_pos = truth.map(|c| c == query).unwrap_or(false);
                let oracle = if rng.bool(*oracle_acc) { truth_pos } else { !truth_pos };
                // Hard examples ("flips") are seen as the wrong class but
                // with diluted confidence — most land in the doubtful band
                // (where the cloud can rescue them), some are confidently
                // wrong (the edge-only accuracy ceiling), matching the
                // calibration profile of the paper's CQ-CNN.
                let (seen_as, sharp) = if rng.bool(*edge_flip) {
                    (!truth_pos, (*sharpness / 3.0).max(1.0))
                } else {
                    (truth_pos, *sharpness)
                };
                let conf = synth_confidence(rng, seen_as, sharp);
                Ok((oracle, Some(conf)))
            }
        }
    }

    /// Edge CNN confidence for a task at classify time.
    fn edge_confidence(&mut self, task: &SimTask) -> crate::Result<f32> {
        match &mut self.mode {
            #[cfg(feature = "pjrt")]
            ComputeMode::Pjrt(ctx) => {
                let probs = ctx.edge_model.infer(&task.crop)?;
                Ok(probs[0].get(1).copied().unwrap_or(0.0))
            }
            ComputeMode::Synthetic { .. } => Ok(task.synth_confidence.unwrap_or(0.0)),
        }
    }

    /// Record a final verdict: metrics, the per-frame trace, the
    /// end-of-pipeline span (`dur` = end-to-end latency) and the verdict
    /// counter by site (`edge` / `cloud` / `degraded`).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &mut self,
        result: &mut SchemeResult,
        scheme: Scheme,
        task_id: u64,
        positive: bool,
        oracle: bool,
        truth: Option<bool>,
        latency: f64,
        t: f64,
        home_edge: u32,
        site: &'static str,
    ) {
        result.vs_oracle.record(positive, oracle);
        if let Some(tr) = truth {
            result.vs_truth.record(positive, tr);
        }
        result.latency.record(latency);
        result.per_frame.push((t, latency, home_edge));
        self.span(scheme, t, task_id, Stage::Verdict, home_edge, latency, site);
        if let Some(reg) = &self.obs {
            reg.inc(
                "surveiledge_harness_verdicts_total",
                &[("scheme", scheme.name()), ("site", site)],
                1,
            );
        }
    }
}

fn node_load(id: u32, sim: &NodeSim, penalty: f64) -> NodeLoad {
    NodeLoad {
        node: NodeId(id),
        queue: sim.queue.len() + sim.busy as usize,
        t_infer: sim.estimator.estimate(),
        penalty,
    }
}

fn service_time(node: u32, sim: &NodeSim, times: &ServiceTimes) -> f64 {
    if node == 0 {
        times.cloud_infer / sim.speed
    } else {
        times.edge_infer / sim.speed
    }
}

type EventHeap = BinaryHeap<Reverse<(HeapKey, u8)>>;
type EventMap = std::collections::HashMap<u64, Event>;

/// Immutable fault context for one scheme run.
struct FaultCtx {
    plan: FaultPlan,
    outage: Option<EdgeOutage>,
}

/// Mutable discrete-event state for one scheme run, bundled so the
/// dispatch / retry / failover paths share one signature.
struct Des {
    nodes: Vec<NodeSim>,
    uplinks: Vec<Uplink>,
    heap: EventHeap,
    events: EventMap,
    seq: u64,
    /// Bytes shipped over any uplink (bandwidth accounting).
    cloud_bytes: u64,
    fstats: FaultStats,
    times: ServiceTimes,
    uplink_bps: f64,
    fx: FaultCtx,
}

impl Des {
    /// Schedule `ev` at time `t`; the returned id cancels it via
    /// `events.remove` (the heap entry then no-ops).
    fn schedule(&mut self, t: f64, ev: Event) -> u64 {
        let id = self.seq;
        self.events.insert(id, ev);
        self.heap.push(Reverse((HeapKey(t, id), 0)));
        self.seq += 1;
        id
    }

    fn enqueue_node(&mut self, n: usize, mut task: SimTask, t: f64) {
        task.t_enqueue = t;
        self.nodes[n].queue.push_back(task);
        self.start_if_idle(n, t);
    }

    fn start_if_idle(&mut self, n: usize, t: f64) {
        if self.nodes[n].busy || self.nodes[n].queue.is_empty() {
            return;
        }
        // Legacy outage: a dead edge holds its queue until recovery
        // (cloud never fails on this path).
        if let Some(o) = self.fx.outage {
            if n > 0 && o.covers(t, n as u32) {
                self.nodes[n].busy = true; // freeze; resume event at recovery
                self.schedule(o.until, Event::NodeResume { node: n as u32 });
                return;
            }
        }
        // Fault-plan crash: the queue is frozen but the node is not
        // marked busy — FaultRecover (or the failover sweep) picks the
        // tasks back up.
        if self.fx.plan.is_down(n as u32, t) {
            return;
        }
        self.nodes[n].busy = true;
        let service =
            service_time(n as u32, &self.nodes[n], &self.times) * self.fx.plan.slowdown(n as u32, t);
        let id = self.schedule(t + service, Event::NodeFinish { node: n as u32 });
        self.nodes[n].finish_ev = Some(id);
    }

    /// Queue a task on an edge's uplink toward the cloud (a retry
    /// retransmits, so the bytes count again).
    fn push_uplink(&mut self, e: usize, mut task: SimTask, t: f64) {
        task.t_enqueue = t;
        self.cloud_bytes += task.wire_bytes;
        self.uplinks[e].queued_bytes += task.wire_bytes;
        self.uplinks[e].queue.push_back(task);
        self.kick_uplink(e, t);
    }

    fn kick_uplink(&mut self, e: usize, t: f64) {
        if !self.uplinks[e].busy {
            if let Some(front) = self.uplinks[e].queue.front() {
                self.uplinks[e].busy = true;
                let transfer = front.wire_bytes as f64 / self.uplink_bps.max(1.0);
                self.schedule(t + transfer, Event::UplinkFinish { edge: e as u32 });
            }
        }
    }
}

/// Everything one multi-scheme comparison run needs. Replaces the old
/// positional `run_all_schemes(cfg, mode_factory)` signature, whose
/// arguments had drifted apart between the CLI, the benches and
/// `tests/harness_integration.rs`.
#[derive(Clone)]
pub struct RunSpec {
    pub cfg: Config,
    /// Schemes to run, in order (defaults to all four).
    pub schemes: Vec<Scheme>,
    /// Fault-schedule override; `None` uses `cfg.faults`.
    pub plan: Option<FaultPlan>,
    /// Request real PJRT inference (needs `--features pjrt` + artifacts).
    pub pjrt: bool,
    /// Shared registry: every scheme run records into it, labelled by
    /// scheme.
    pub obs: Option<Registry>,
}

impl RunSpec {
    pub fn new(cfg: Config) -> RunSpec {
        RunSpec { cfg, schemes: Scheme::all().to_vec(), plan: None, pjrt: false, obs: None }
    }

    pub fn schemes(mut self, schemes: &[Scheme]) -> RunSpec {
        self.schemes = schemes.to_vec();
        self
    }

    pub fn plan(mut self, plan: FaultPlan) -> RunSpec {
        self.plan = Some(plan);
        self
    }

    pub fn pjrt(mut self, pjrt: bool) -> RunSpec {
        self.pjrt = pjrt;
        self
    }

    pub fn observe(mut self, reg: Registry) -> RunSpec {
        self.obs = Some(reg);
        self
    }
}

/// Run every scheme in the spec on one scenario (the paper's table
/// layout). Each scheme gets a fresh harness built from the spec.
pub fn run_all_schemes(spec: &RunSpec) -> crate::Result<Vec<SchemeResult>> {
    spec.schemes
        .iter()
        .map(|&scheme| {
            let mode = standard_mode(&spec.cfg, spec.pjrt)?;
            let mut b = Harness::builder(spec.cfg.clone()).mode(mode);
            if let Some(plan) = &spec.plan {
                b = b.plan(plan.clone());
            }
            if let Some(reg) = &spec.obs {
                b = b.observe(reg.clone());
            }
            b.build().run(scheme)
        })
        .collect()
}

/// Deprecated positional form of [`run_all_schemes`].
#[deprecated(since = "0.7.0", note = "use run_all_schemes(&RunSpec)")]
pub fn run_all_schemes_with(
    cfg: &Config,
    mode_factory: &mut dyn FnMut() -> crate::Result<ComputeMode>,
) -> crate::Result<Vec<SchemeResult>> {
    Scheme::all()
        .into_iter()
        .map(|scheme| {
            let mode = mode_factory()?;
            Harness::builder(cfg.clone()).mode(mode).build().run(scheme)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_mode() -> ComputeMode {
        ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
    }

    fn small_cfg() -> Config {
        Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() }
    }

    #[test]
    fn single_edge_schemes_have_expected_shape() {
        let cfg = small_cfg();
        let run = |scheme| {
            let mut h = Harness::builder(cfg.clone()).mode(synth_mode()).build();
            h.run(scheme).unwrap()
        };
        let se = run(Scheme::SurveilEdge);
        let eo = run(Scheme::EdgeOnly);
        let co = run(Scheme::CloudOnly);
        assert!(se.tasks > 10, "too few tasks: {}", se.tasks);
        // Cloud-only: accuracy 1.0 (oracle == verdict), max bandwidth.
        assert!((co.row.accuracy - 1.0).abs() < 1e-9, "cloud-only F2 {}", co.row.accuracy);
        assert!(co.row.bandwidth_mb > se.row.bandwidth_mb, "cloud-only must use most bandwidth");
        // Edge-only: zero bandwidth, lowest accuracy.
        assert_eq!(eo.row.bandwidth_mb, 0.0);
        assert!(eo.row.accuracy <= se.row.accuracy + 0.05, "edge-only {} vs SE {}", eo.row.accuracy, se.row.accuracy);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg();
        let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
        let mut h2 = Harness::builder(cfg).mode(synth_mode()).build();
        let a = h1.run(Scheme::SurveilEdge).unwrap();
        let b = h2.run(Scheme::SurveilEdge).unwrap();
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.latency.len(), b.latency.len());
        assert!((a.row.avg_latency - b.row.avg_latency).abs() < 1e-12);
    }

    #[test]
    fn all_tasks_get_verdicts() {
        let cfg = small_cfg();
        let mut h = Harness::builder(cfg).mode(synth_mode()).build();
        let r = h.run(Scheme::SurveilEdge).unwrap();
        // Every emitted task is eventually answered (drain horizon).
        assert_eq!(r.latency.len() as u64, r.tasks);
    }

    #[test]
    fn heterogeneous_edge_only_slower_than_surveiledge() {
        let cfg = Config { duration: 120.0, frame_h: 48, frame_w: 64, ..Config::heterogeneous() };
        let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
        let eo = h1.run(Scheme::EdgeOnly).unwrap();
        let mut h2 = Harness::builder(cfg).mode(synth_mode()).build();
        let se = h2.run(Scheme::SurveilEdge).unwrap();
        assert!(
            se.row.avg_latency < eo.row.avg_latency,
            "SurveilEdge {} should beat edge-only {}",
            se.row.avg_latency,
            eo.row.avg_latency
        );
    }

    #[test]
    fn fault_free_run_reports_quiet_fault_stats() {
        let cfg = small_cfg();
        let mut h = Harness::builder(cfg).mode(synth_mode()).build();
        let r = h.run(Scheme::SurveilEdge).unwrap();
        assert!(!r.faults.any(), "fault-free run must not retry/reroute/degrade");
        assert_eq!(r.faults.lost, 0);
    }

    #[test]
    fn empty_plan_matches_default_run_exactly() {
        let cfg = small_cfg();
        let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
        let mut h2 = Harness::builder(cfg).mode(synth_mode()).plan(FaultPlan::none()).build();
        let a = h1.run(Scheme::SurveilEdge).unwrap();
        let b = h2.run(Scheme::SurveilEdge).unwrap();
        assert_eq!(a.tasks, b.tasks);
        assert!((a.row.avg_latency - b.row.avg_latency).abs() < 1e-12);
        assert!((a.row.bandwidth_mb - b.row.bandwidth_mb).abs() < 1e-12);
    }

    #[test]
    fn slow_window_inflates_edge_latency() {
        let cfg = small_cfg();
        let mut base = Harness::builder(cfg.clone()).mode(synth_mode()).build();
        let b = base.run(Scheme::EdgeOnly).unwrap();
        let plan = FaultPlan {
            slow: vec![crate::faults::SlowWindow { node: 1, from: 0.0, until: 60.0, factor: 8.0 }],
            ..FaultPlan::none()
        };
        let mut slowed = Harness::builder(cfg).mode(synth_mode()).plan(plan).build();
        let s = slowed.run(Scheme::EdgeOnly).unwrap();
        assert!(
            s.row.avg_latency > b.row.avg_latency,
            "slowdown {} should exceed base {}",
            s.row.avg_latency,
            b.row.avg_latency
        );
        assert_eq!(s.faults.lost, 0, "slow tasks still drain");
        assert_eq!(s.latency.len() as u64, s.tasks);
    }

    #[test]
    fn cloud_crash_degrades_doubtfuls_instead_of_stranding() {
        let cfg = small_cfg();
        let plan = FaultPlan {
            crashes: vec![crate::faults::CrashWindow { node: 0, from: 5.0, until: 100.0 }],
            ..FaultPlan::none()
        };
        let mut h = Harness::builder(cfg).mode(synth_mode()).plan(plan).build();
        let r = h.run(Scheme::SurveilEdge).unwrap();
        assert_eq!(r.faults.lost, 0, "no task may be stranded by the cloud outage");
        assert_eq!(r.latency.len() as u64, r.tasks);
        assert!(r.faults.degraded > 0, "cloud outage must force edge-local verdicts");
    }

    #[test]
    fn builder_defaults_and_report_schema() {
        let h = Harness::builder(small_cfg()).build();
        assert!(matches!(h.mode, ComputeMode::Synthetic { .. }));
        assert!(h.plan.is_empty(), "default plan comes from cfg.faults (empty here)");
        assert!(h.obs.is_none());
        let mut h = Harness::builder(small_cfg()).mode(synth_mode()).build();
        let r = h.run(Scheme::SurveilEdge).unwrap();
        let rep = r.report();
        assert_eq!(rep.kind, "scheme_run");
        assert_eq!(rep.name, r.row.scheme);
        assert_eq!(rep.get("tasks"), Some(r.tasks as f64));
        assert_eq!(rep.get("faults_lost"), Some(0.0));
        assert!(rep.get("p99_latency_s").unwrap() >= rep.get("p50_latency_s").unwrap());
    }

    #[test]
    fn observed_run_emits_spans_and_valid_exports() {
        let reg = Registry::new();
        let mut h =
            Harness::builder(small_cfg()).mode(synth_mode()).observe(reg.clone()).build();
        let r = h.run(Scheme::SurveilEdge).unwrap();
        assert!(reg.event_count() > 0, "an observed run must record spans");
        let sl = [("scheme", r.row.scheme.as_str())];
        assert_eq!(reg.counter("surveiledge_harness_tasks_total", &sl), r.tasks);
        assert_eq!(reg.counter("surveiledge_harness_uploads_total", &sl), r.uploads);
        crate::obs::validate_prometheus(&reg.export_prometheus()).unwrap();
        assert_eq!(
            crate::obs::validate_jsonl(&reg.export_jsonl()).unwrap(),
            reg.event_count()
        );
    }

    #[test]
    fn run_spec_drives_selected_schemes() {
        let spec = RunSpec::new(small_cfg()).schemes(&[Scheme::SurveilEdge, Scheme::EdgeOnly]);
        let results = run_all_schemes(&spec).unwrap();
        assert_eq!(results.len(), 2);
        assert_ne!(results[0].row.scheme, results[1].row.scheme);
    }

    #[test]
    fn finetune_corpus_shapes() {
        let (px, lb) = finetune_corpus(ClassId::Moped, 64, 3);
        assert_eq!(px.len(), 64 * 32 * 32 * 3);
        assert_eq!(lb.len(), 64);
        assert_eq!(lb.iter().filter(|&&l| l == 1).count(), 32);
    }
}
