//! Experiment harness: discrete-event reproduction of the paper's
//! evaluation (Tables II–IV, Figs. 6–8).
//!
//! The harness replays a surveillance workload through the full pipeline
//! under each of the four schemes. Logical (simulated) time carries the
//! queueing dynamics — service times are calibrated to the paper's
//! hardware (edge CPU MobileNet, cloud P4 ResNet-152, shared uplink) — so
//! the experiments run the paper's multi-hour regime in seconds on this
//! one-core host.
//!
//! The module is three layers plus this facade:
//!
//! * [`engine`] — pure DES mechanics: the event heap, node/uplink queues,
//!   fault and heartbeat scheduling, the drain horizon. Scheme-agnostic.
//! * [`scheme`] — the [`SchemePolicy`] trait and the four built-in
//!   policies; every per-scheme behavioral difference lives here.
//! * [`pipeline`] — per-task stage logic (detect → classify → band
//!   decision) shared *verbatim* with the live `nodes::EdgeWorker`
//!   substrate, plus the [`ComputeMode`] compute backends.
//!
//! Compute itself has two modes:
//!
//! * `ComputeMode::Pjrt` (requires `--features pjrt`) — every
//!   classification is a *real* PJRT call on the AOT artifacts (real CNN
//!   confidences; logical service times).
//! * `ComputeMode::Synthetic` — confidences drawn from a calibrated
//!   distribution (for fast sweeps and benches without artifacts; the
//!   default build's only mode).
//!
//! Network model: each edge has a FIFO uplink of `uplink_mbps`; a crop's
//! wire size models the *native-resolution* crop the paper ships (our
//! 96×128 frames stand in for 1080p — an `HD_SCALE` area factor,
//! DESIGN.md §3), which is what makes cloud-only bandwidth-bound, as in
//! the paper.

pub mod engine;
pub mod pipeline;
pub mod scheme;

#[cfg(test)]
mod tests;

use crate::config::{Config, Scheme};
use crate::faults::FaultPlan;
use crate::metrics::{Confusion, FaultStats, LatencyRecorder, SchemeRow};
use crate::obs::{Registry, Report, SpanEvent, Stage};
use crate::query::{QuerySet, QueryVerdict};

pub use pipeline::{
    classify_stage, detect_crops, finetune_corpus, standard_mode, ComputeMode, DetectedCrop,
    EdgeAction, EdgeOutcome, PipelineCtx, EDGE_SPLIT,
};
#[cfg(feature = "pjrt")]
pub use pipeline::PjrtCtx;
pub use scheme::{
    policy_for, CloudOnlyPolicy, EdgeOnlyPolicy, RouteCtx, SchemePolicy, SurveilEdgeFixedPolicy,
    SurveilEdgePolicy,
};

/// Area factor mapping our synthetic frame resolution to the 1080p the
/// paper transmits (linear scale ~15x => area ~225x).
pub const HD_SCALE: u64 = 225;

/// Calibrated service-time constants (paper-era hardware, DESIGN.md §3).
#[derive(Clone, Copy, Debug)]
pub struct ServiceTimes {
    /// Edge CQ-CNN per-crop inference at speed 1.0 (i7 CPU, MobileNet).
    pub edge_infer: f64,
    /// Cloud high-accuracy CNN per-crop inference (P4 GPU, ResNet-152).
    pub cloud_infer: f64,
}

impl Default for ServiceTimes {
    fn default() -> ServiceTimes {
        ServiceTimes { edge_infer: 0.28, cloud_infer: 0.12 }
    }
}

/// Result of one scheme run.
pub struct SchemeResult {
    pub row: SchemeRow,
    pub latency: LatencyRecorder,
    /// (verdict time, latency, home edge) triples — Figs. 6–8 (b)-(d).
    pub per_frame: Vec<(f64, f64, u32)>,
    pub vs_oracle: Confusion,
    pub vs_truth: Confusion,
    pub uploads: u64,
    pub tasks: u64,
    /// Mean doubtful-band width over the run (ablation diagnostics).
    pub mean_band_width: f64,
    /// Recovery metrics under fault injection (all-zero without a plan).
    pub faults: FaultStats,
    /// Per-query verdict stream, in verdict order (empty without an
    /// attached [`QuerySet`]).
    pub query_verdicts: Vec<QueryVerdict>,
    /// One `query_run` report per attached query, in id order.
    pub per_query: Vec<Report>,
}

impl SchemeResult {
    /// Collapse into the one stable [`Report`] schema every consumer
    /// (CLI, benches, integration tests, EXPERIMENTS.md recipes) reads
    /// metrics through: kind `scheme_run`, named after the scheme.
    pub fn report(&self) -> Report {
        let mut r = Report::new("scheme_run", &self.row.scheme);
        r.push("accuracy_f2", self.row.accuracy);
        r.push("avg_latency_s", self.row.avg_latency);
        r.push("p50_latency_s", self.latency.percentile(0.5));
        r.push("p99_latency_s", self.latency.percentile(0.99));
        r.push("bandwidth_mb", self.row.bandwidth_mb);
        r.push("tasks", self.tasks as f64);
        r.push("uploads", self.uploads as f64);
        r.push("mean_band_width", self.mean_band_width);
        self.faults.fill_report(&mut r);
        r
    }
}

/// Fault injection: an edge node goes dark for a time window. Tasks that
/// would run there must be re-routed (SurveilEdge) or stall until
/// recovery (schemes without an allocator) — an extension experiment
/// beyond the paper's evaluation (DESIGN.md §8).
#[derive(Clone, Copy, Debug)]
pub struct EdgeOutage {
    pub edge: u32,
    pub from: f64,
    pub until: f64,
}

impl EdgeOutage {
    pub fn covers(&self, t: f64, edge: u32) -> bool {
        edge == self.edge && t >= self.from && t < self.until
    }
}

/// The scheme runner.
pub struct Harness {
    pub cfg: Config,
    pub times: ServiceTimes,
    pub mode: ComputeMode,
    /// Optional fault injection (legacy single-window outage).
    pub outage: Option<EdgeOutage>,
    /// Scripted fault plan (crashes, drops, delays, slowdowns) — defaults
    /// to `cfg.faults`; `FaultPlan::none()` leaves the run fault-free.
    pub plan: FaultPlan,
    /// Observability sink: per-task stage spans + counters/gauges/
    /// histograms accumulate here when attached (`builder(..).observe(..)`).
    pub obs: Option<Registry>,
    /// Admitted query set: with one attached, every verdict fans out into
    /// per-query threshold decisions (work sharing) and the result
    /// carries per-query streams/reports. `None` = classic single-query
    /// run, byte-identical to pre-query builds.
    pub queries: Option<QuerySet>,
}

/// Builder for [`Harness`]:
///
/// ```ignore
/// let mut h = Harness::builder(cfg)
///     .mode(ComputeMode::synthetic_default())
///     .plan(plan)
///     .observe(registry)
///     .build();
/// ```
pub struct HarnessBuilder {
    cfg: Config,
    times: ServiceTimes,
    mode: Option<ComputeMode>,
    outage: Option<EdgeOutage>,
    plan: Option<FaultPlan>,
    obs: Option<Registry>,
    queries: Option<QuerySet>,
}

impl HarnessBuilder {
    /// Compute source (defaults to [`ComputeMode::synthetic_default`]).
    pub fn mode(mut self, mode: ComputeMode) -> HarnessBuilder {
        self.mode = Some(mode);
        self
    }

    /// Override the calibrated service-time constants.
    pub fn times(mut self, times: ServiceTimes) -> HarnessBuilder {
        self.times = times;
        self
    }

    /// Legacy single-window edge outage.
    pub fn outage(mut self, outage: EdgeOutage) -> HarnessBuilder {
        self.outage = Some(outage);
        self
    }

    /// Override the fault schedule (defaults to the config's `[faults]`).
    pub fn plan(mut self, plan: FaultPlan) -> HarnessBuilder {
        self.plan = Some(plan);
        self
    }

    /// Attach a metric registry; the run records spans and metrics into it.
    pub fn observe(mut self, reg: Registry) -> HarnessBuilder {
        self.obs = Some(reg);
        self
    }

    /// Attach an admitted query set; the run fans every shared verdict
    /// out into per-query decisions and result streams.
    pub fn queries(mut self, queries: QuerySet) -> HarnessBuilder {
        self.queries = Some(queries);
        self
    }

    pub fn build(self) -> Harness {
        let HarnessBuilder { cfg, times, mode, outage, plan, obs, queries } = self;
        let plan = plan.unwrap_or_else(|| cfg.faults.clone());
        let mode = mode.unwrap_or_else(ComputeMode::synthetic_default);
        Harness { cfg, times, mode, outage, plan, obs, queries }
    }
}

impl Harness {
    /// Start building a harness for `cfg` (see [`HarnessBuilder`]).
    pub fn builder(cfg: Config) -> HarnessBuilder {
        HarnessBuilder {
            cfg,
            times: ServiceTimes::default(),
            mode: None,
            outage: None,
            plan: None,
            obs: None,
            queries: None,
        }
    }

    /// Record one stage span (no-op without an attached registry): the
    /// per-scheme/per-stage latency histogram plus the timeline event.
    /// `scheme` is the policy's name (a custom policy labels its own runs).
    fn span(&self, scheme: &str, t: f64, task: u64, stage: Stage, node: u32, dur: f64, detail: &str) {
        if let Some(reg) = &self.obs {
            let dur = if dur.is_finite() { dur.max(0.0) } else { 0.0 };
            reg.observe(
                "surveiledge_stage_seconds",
                &[("scheme", scheme), ("stage", stage.as_str())],
                dur,
            );
            reg.span(SpanEvent {
                t,
                task,
                stage,
                node,
                dur,
                scheme: scheme.to_string(),
                detail: detail.to_string(),
            });
        }
    }

    /// Run one built-in scheme over the configured scenario.
    pub fn run(&mut self, scheme: Scheme) -> crate::Result<SchemeResult> {
        self.run_policy(policy_for(scheme))
    }

    /// Run an arbitrary [`SchemePolicy`] — the extension point the four
    /// built-in schemes themselves go through.
    pub fn run_policy(&mut self, policy: &dyn SchemePolicy) -> crate::Result<SchemeResult> {
        engine::run_scheme(self, policy)
    }
}

/// Everything one multi-scheme comparison run needs. Replaces the old
/// positional `run_all_schemes(cfg, mode_factory)` signature, whose
/// arguments had drifted apart between the CLI, the benches and
/// `tests/harness_integration.rs`.
#[derive(Clone)]
pub struct RunSpec {
    pub cfg: Config,
    /// Schemes to run, in order (defaults to all four).
    pub schemes: Vec<Scheme>,
    /// Fault-schedule override; `None` uses `cfg.faults`.
    pub plan: Option<FaultPlan>,
    /// Request real PJRT inference (needs `--features pjrt` + artifacts).
    pub pjrt: bool,
    /// Shared registry: every scheme run records into it, labelled by
    /// scheme.
    pub obs: Option<Registry>,
    /// Query set every scheme runs against (each thread gets a clone).
    pub queries: Option<QuerySet>,
}

impl RunSpec {
    pub fn new(cfg: Config) -> RunSpec {
        RunSpec {
            cfg,
            schemes: Scheme::all().to_vec(),
            plan: None,
            pjrt: false,
            obs: None,
            queries: None,
        }
    }

    pub fn schemes(mut self, schemes: &[Scheme]) -> RunSpec {
        self.schemes = schemes.to_vec();
        self
    }

    pub fn plan(mut self, plan: FaultPlan) -> RunSpec {
        self.plan = Some(plan);
        self
    }

    pub fn pjrt(mut self, pjrt: bool) -> RunSpec {
        self.pjrt = pjrt;
        self
    }

    pub fn observe(mut self, reg: Registry) -> RunSpec {
        self.obs = Some(reg);
        self
    }

    pub fn queries(mut self, queries: QuerySet) -> RunSpec {
        self.queries = Some(queries);
        self
    }
}

/// Run every scheme in the spec on one scenario (the paper's table
/// layout), one OS thread per scheme.
///
/// Each scheme gets a fresh harness built from the spec, so the runs
/// share no mutable state and each result is *byte-identical* to what a
/// sequential loop at the same seed produces (the DES is deterministic
/// per scheme). With an attached registry, every scheme records into a
/// private child registry which is folded into the shared one in spec
/// order after the join — reproducing the sequential export layout
/// exactly (all per-scheme series are scheme-labelled; unlabelled series
/// merge in the same order a sequential loop wrote them).
///
/// The `ComputeMode` is built *inside* each thread: the PJRT context is
/// deliberately not `Send` (it owns a thread-local client handle).
pub fn run_all_schemes(spec: &RunSpec) -> crate::Result<Vec<SchemeResult>> {
    let n = spec.schemes.len();
    let mut slots: Vec<Option<crate::Result<SchemeResult>>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let child_regs: Vec<Option<Registry>> =
        spec.schemes.iter().map(|_| spec.obs.as_ref().map(|_| Registry::new())).collect();
    std::thread::scope(|scope| {
        for ((&scheme, slot), child) in
            spec.schemes.iter().zip(slots.iter_mut()).zip(child_regs.iter())
        {
            let cfg = &spec.cfg;
            let plan = &spec.plan;
            let queries = &spec.queries;
            let pjrt = spec.pjrt;
            scope.spawn(move || {
                *slot = Some((|| {
                    let mode = standard_mode(cfg, pjrt)?;
                    let mut b = Harness::builder(cfg.clone()).mode(mode);
                    if let Some(plan) = plan {
                        b = b.plan(plan.clone());
                    }
                    if let Some(reg) = child {
                        b = b.observe(reg.clone());
                    }
                    if let Some(qs) = queries {
                        b = b.queries(qs.clone());
                    }
                    b.build().run(scheme)
                })());
            });
        }
    });
    if let Some(shared) = &spec.obs {
        for child in child_regs.iter().flatten() {
            shared.merge_from(child);
        }
    }
    slots.into_iter().map(|s| s.expect("scheme thread completed")).collect()
}
