//! Scheme policies: every per-scheme behavioral difference, behind one
//! trait.
//!
//! The DES engine (`harness::engine`) and the live workers
//! (`nodes::EdgeWorker`) are scheme-agnostic; they call into a
//! [`SchemePolicy`] at the four points where the paper's schemes diverge:
//!
//! * **controller** — adaptive eqs. 8–9 band vs the fixed α=0.8/β=0.1
//!   baseline,
//! * **route** — the eq. 7 allocator vs pinned-to-home vs pinned-to-cloud,
//! * **decide** — band decision vs edge-only's hard 0.5 split,
//! * **failure handling** — whether a scheme runs the stale-heartbeat
//!   failover sweep, and whether a failed remote delivery may fall back
//!   to the home edge.
//!
//! Adding a scheme means adding an impl here — the event loop and the
//! live workers never change.

use crate::config::{Config, Scheme};
use crate::nodes::node_alive;
use crate::obs::Registry;
use crate::paramdb::ParamDb;
use crate::sched::{
    allocate, record_allocation, record_exclusion, weight_penalties, BandDecision, NodeLoad,
    ThresholdConfig, ThresholdController,
};
use crate::types::NodeId;

use super::engine::{NodeSim, Uplink};
use super::pipeline::EDGE_SPLIT;
use super::{EdgeOutage, HD_SCALE};

/// Everything a routing decision may consult: the task's home edge, the
/// current (simulated) time, and read-only views of the cluster state.
pub struct RouteCtx<'a> {
    /// Home edge of the task being routed (node index, 1-based).
    pub home: u32,
    /// Current simulated time.
    pub t: f64,
    pub cfg: &'a Config,
    /// Node 0 = cloud; 1..=n = edges.
    pub nodes: &'a [NodeSim],
    /// Per-edge uplink state (index 0 = edge 1).
    pub uplinks: &'a [Uplink],
    /// Parameter DB — heartbeat keys drive liveness filtering.
    pub db: &'a ParamDb,
    /// Legacy single-window outage, if any.
    pub outage: Option<EdgeOutage>,
    /// Attached registry (allocation decisions are recorded into it).
    pub obs: Option<&'a Registry>,
    /// eq. 7 deadline weight of the most demanding query covering this
    /// task (1.0 without a query set — a uniform scale preserves the
    /// argmin, so query-less routing is byte-identical).
    pub route_weight: f64,
    /// Is this edge's uplink circuit breaker open (`crate::overload`)?
    /// An open breaker removes the cloud from candidacy even while its
    /// heartbeat is fresh — the *link* is the problem, not the node.
    /// Always `false` without an `[overload]` block.
    pub cloud_uplink_open: bool,
}

/// One scheme's behavior. Default methods encode the common case; each
/// impl overrides only where its scheme actually diverges.
pub trait SchemePolicy: Sync {
    /// The built-in scheme this policy reports as (used for labels and
    /// result rows; custom policies may still override [`Self::name`]).
    fn scheme(&self) -> Scheme;

    /// Human-readable name — the `Report` / table / span label key.
    fn name(&self) -> &'static str {
        self.scheme().name()
    }

    /// Per-edge threshold controller. Default: the adaptive eqs. 8–9
    /// band starting at α₀ = 0.8.
    fn controller(&self, gamma1: f64, gamma2: f64, interval: f64) -> ThresholdController {
        ThresholdController::new(0.8, ThresholdConfig { gamma1, gamma2, interval })
    }

    /// Destination for a new (or re-routed) task.
    fn route(&self, ctx: &RouteCtx<'_>) -> NodeId;

    /// Band decision on an edge confidence. Default: the controller's
    /// [β, α] band.
    fn decide(&self, controller: &ThresholdController, confidence: f32) -> BandDecision {
        controller.decide(confidence)
    }

    /// Does this scheme schedule the stale-heartbeat failover sweep that
    /// re-queues a crashed node's stranded tasks through the allocator?
    fn schedules_failover_sweep(&self) -> bool {
        false
    }

    /// May a failed remote delivery fall back to the home edge once the
    /// cloud is dead or the attempt budget is spent? Cloud-only answers
    /// `false`: it has no edge fallback and keeps retrying.
    fn falls_back_to_edge(&self) -> bool {
        true
    }
}

/// The paper's full scheme: eq. 7 allocation + adaptive thresholds +
/// heartbeat-driven failover.
pub struct SurveilEdgePolicy;

impl SchemePolicy for SurveilEdgePolicy {
    fn scheme(&self) -> Scheme {
        Scheme::SurveilEdge
    }

    fn route(&self, ctx: &RouteCtx<'_>) -> NodeId {
        // eq. 7 over {home edge first, other edges, cloud}; edges under an
        // injected outage or with a stale heartbeat are not candidates
        // (failover). Without heartbeats (fault-free runs) `node_alive` is
        // vacuously true.
        let dead = |e: u32| {
            ctx.outage.is_some_and(|o| o.covers(ctx.t, e)) || !node_alive(ctx.db, e, ctx.t)
        };
        let mut cands: Vec<NodeLoad> = Vec::with_capacity(ctx.nodes.len());
        if !dead(ctx.home) {
            cands.push(ctx.nodes[ctx.home as usize].load(ctx.home, 0.0));
        }
        for i in 1..ctx.nodes.len() as u32 {
            if i != ctx.home && !dead(i) {
                cands.push(ctx.nodes[i as usize].load(i, 0.0));
            }
        }
        // Cloud penalty: rtt + typical crop transfer + current uplink
        // backlog on this edge's link.
        let backlog = ctx.uplinks[(ctx.home - 1) as usize].queued_bytes() as f64;
        let upload = ctx.cfg.rtt
            + (backlog + 24.0 * 24.0 * 3.0 * HD_SCALE as f64) / (ctx.cfg.uplink_mbps * 125_000.0);
        if node_alive(ctx.db, 0, ctx.t) {
            if ctx.cloud_uplink_open {
                // Breaker open: the cloud is alive but its uplink is
                // shunned. Composes with the heartbeat exclusion above
                // (a dead cloud is never a candidate either way).
                if let Some(reg) = ctx.obs {
                    record_exclusion(reg, self.name(), NodeId::CLOUD, "circuit_open");
                }
            } else {
                cands.push(ctx.nodes[0].load(0, upload));
            }
        }
        weight_penalties(&mut cands, ctx.route_weight);
        let dest = allocate(&cands).unwrap_or(NodeId(ctx.home));
        if let Some(reg) = ctx.obs {
            record_allocation(reg, self.name(), dest, &cands);
        }
        dest
    }

    fn schedules_failover_sweep(&self) -> bool {
        true
    }
}

/// Fixed-threshold baseline: home-pinned, α=0.8 / β=0.1 forever.
pub struct SurveilEdgeFixedPolicy;

impl SchemePolicy for SurveilEdgeFixedPolicy {
    fn scheme(&self) -> Scheme {
        Scheme::SurveilEdgeFixed
    }

    fn controller(&self, _gamma1: f64, _gamma2: f64, _interval: f64) -> ThresholdController {
        ThresholdController::fixed()
    }

    fn route(&self, ctx: &RouteCtx<'_>) -> NodeId {
        NodeId(ctx.home)
    }
}

/// Edge-only baseline: no cloud path at all — hard 0.5 split at the edge.
pub struct EdgeOnlyPolicy;

impl SchemePolicy for EdgeOnlyPolicy {
    fn scheme(&self) -> Scheme {
        Scheme::EdgeOnly
    }

    fn route(&self, ctx: &RouteCtx<'_>) -> NodeId {
        NodeId(ctx.home)
    }

    fn decide(&self, _controller: &ThresholdController, confidence: f32) -> BandDecision {
        if confidence >= EDGE_SPLIT {
            BandDecision::Positive
        } else {
            BandDecision::Negative
        }
    }
}

/// Cloud-only baseline: every crop ships over the uplink.
pub struct CloudOnlyPolicy;

impl SchemePolicy for CloudOnlyPolicy {
    fn scheme(&self) -> Scheme {
        Scheme::CloudOnly
    }

    fn route(&self, _ctx: &RouteCtx<'_>) -> NodeId {
        NodeId::CLOUD
    }

    fn falls_back_to_edge(&self) -> bool {
        false
    }
}

/// The built-in policy for a [`Scheme`] (unit structs, so `'static`).
pub fn policy_for(scheme: Scheme) -> &'static dyn SchemePolicy {
    match scheme {
        Scheme::SurveilEdge => &SurveilEdgePolicy,
        Scheme::SurveilEdgeFixed => &SurveilEdgeFixedPolicy,
        Scheme::EdgeOnly => &EdgeOnlyPolicy,
        Scheme::CloudOnly => &CloudOnlyPolicy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_match_scheme_names() {
        for scheme in Scheme::all() {
            let p = policy_for(scheme);
            assert_eq!(p.scheme(), scheme);
            assert_eq!(p.name(), scheme.name());
        }
    }

    #[test]
    fn controllers_match_their_schemes() {
        let fixed = policy_for(Scheme::SurveilEdgeFixed).controller(0.1, 0.25, 1.0);
        assert!((fixed.alpha - 0.8).abs() < 1e-12);
        assert!((fixed.beta - 0.1).abs() < 1e-12);
        let mut adaptive = policy_for(Scheme::SurveilEdge).controller(0.1, 0.25, 1.0);
        let a0 = adaptive.alpha;
        adaptive.update(10, 1.0); // overload: the band must narrow
        assert!(adaptive.alpha < a0);
    }

    #[test]
    fn edge_only_decides_on_a_hard_split() {
        let ctl = policy_for(Scheme::EdgeOnly).controller(0.1, 0.25, 1.0);
        let p = policy_for(Scheme::EdgeOnly);
        assert_eq!(p.decide(&ctl, 0.51), BandDecision::Positive);
        assert_eq!(p.decide(&ctl, 0.49), BandDecision::Negative);
        // Never doubtful, even where the adaptive band would be.
        assert_eq!(policy_for(Scheme::SurveilEdge).decide(&ctl, 0.5), BandDecision::Doubtful);
    }

    #[test]
    fn only_surveiledge_runs_the_failover_sweep() {
        for scheme in Scheme::all() {
            let sweep = policy_for(scheme).schedules_failover_sweep();
            assert_eq!(sweep, scheme == Scheme::SurveilEdge, "{scheme:?}");
        }
    }

    #[test]
    fn only_cloud_only_never_falls_back_to_edge() {
        for scheme in Scheme::all() {
            let falls_back = policy_for(scheme).falls_back_to_edge();
            assert_eq!(falls_back, scheme != Scheme::CloudOnly, "{scheme:?}");
        }
    }
}
