//! Harness-level tests: scheme shapes, determinism, fault handling, the
//! builder/report schema, and the parallel `run_all_schemes` equivalence
//! contract. Engine-internal invariants (HeapKey ordering) live in
//! `engine.rs`; the stage layer's tests live in `pipeline.rs`.

use super::*;
use crate::types::ClassId;

fn synth_mode() -> ComputeMode {
    ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
}

fn small_cfg() -> Config {
    Config { duration: 60.0, frame_h: 48, frame_w: 64, ..Config::single_edge() }
}

#[test]
fn single_edge_schemes_have_expected_shape() {
    let cfg = small_cfg();
    let run = |scheme| {
        let mut h = Harness::builder(cfg.clone()).mode(synth_mode()).build();
        h.run(scheme).unwrap()
    };
    let se = run(Scheme::SurveilEdge);
    let eo = run(Scheme::EdgeOnly);
    let co = run(Scheme::CloudOnly);
    assert!(se.tasks > 10, "too few tasks: {}", se.tasks);
    // Cloud-only: accuracy 1.0 (oracle == verdict), max bandwidth.
    assert!((co.row.accuracy - 1.0).abs() < 1e-9, "cloud-only F2 {}", co.row.accuracy);
    assert!(co.row.bandwidth_mb > se.row.bandwidth_mb, "cloud-only must use most bandwidth");
    // Edge-only: zero bandwidth, lowest accuracy.
    assert_eq!(eo.row.bandwidth_mb, 0.0);
    assert!(eo.row.accuracy <= se.row.accuracy + 0.05, "edge-only {} vs SE {}", eo.row.accuracy, se.row.accuracy);
}

#[test]
fn deterministic_given_seed() {
    let cfg = small_cfg();
    let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
    let mut h2 = Harness::builder(cfg).mode(synth_mode()).build();
    let a = h1.run(Scheme::SurveilEdge).unwrap();
    let b = h2.run(Scheme::SurveilEdge).unwrap();
    assert_eq!(a.tasks, b.tasks);
    assert_eq!(a.latency.len(), b.latency.len());
    assert!((a.row.avg_latency - b.row.avg_latency).abs() < 1e-12);
}

#[test]
fn all_tasks_get_verdicts() {
    let cfg = small_cfg();
    let mut h = Harness::builder(cfg).mode(synth_mode()).build();
    let r = h.run(Scheme::SurveilEdge).unwrap();
    // Every emitted task is eventually answered (drain horizon).
    assert_eq!(r.latency.len() as u64, r.tasks);
}

#[test]
fn heterogeneous_edge_only_slower_than_surveiledge() {
    let cfg = Config { duration: 120.0, frame_h: 48, frame_w: 64, ..Config::heterogeneous() };
    let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
    let eo = h1.run(Scheme::EdgeOnly).unwrap();
    let mut h2 = Harness::builder(cfg).mode(synth_mode()).build();
    let se = h2.run(Scheme::SurveilEdge).unwrap();
    assert!(
        se.row.avg_latency < eo.row.avg_latency,
        "SurveilEdge {} should beat edge-only {}",
        se.row.avg_latency,
        eo.row.avg_latency
    );
}

#[test]
fn fault_free_run_reports_quiet_fault_stats() {
    let cfg = small_cfg();
    let mut h = Harness::builder(cfg).mode(synth_mode()).build();
    let r = h.run(Scheme::SurveilEdge).unwrap();
    assert!(!r.faults.any(), "fault-free run must not retry/reroute/degrade");
    assert_eq!(r.faults.lost, 0);
}

#[test]
fn empty_plan_matches_default_run_exactly() {
    let cfg = small_cfg();
    let mut h1 = Harness::builder(cfg.clone()).mode(synth_mode()).build();
    let mut h2 = Harness::builder(cfg).mode(synth_mode()).plan(FaultPlan::none()).build();
    let a = h1.run(Scheme::SurveilEdge).unwrap();
    let b = h2.run(Scheme::SurveilEdge).unwrap();
    assert_eq!(a.tasks, b.tasks);
    assert!((a.row.avg_latency - b.row.avg_latency).abs() < 1e-12);
    assert!((a.row.bandwidth_mb - b.row.bandwidth_mb).abs() < 1e-12);
}

#[test]
fn slow_window_inflates_edge_latency() {
    let cfg = small_cfg();
    let mut base = Harness::builder(cfg.clone()).mode(synth_mode()).build();
    let b = base.run(Scheme::EdgeOnly).unwrap();
    let plan = FaultPlan {
        slow: vec![crate::faults::SlowWindow { node: 1, from: 0.0, until: 60.0, factor: 8.0 }],
        ..FaultPlan::none()
    };
    let mut slowed = Harness::builder(cfg).mode(synth_mode()).plan(plan).build();
    let s = slowed.run(Scheme::EdgeOnly).unwrap();
    assert!(
        s.row.avg_latency > b.row.avg_latency,
        "slowdown {} should exceed base {}",
        s.row.avg_latency,
        b.row.avg_latency
    );
    assert_eq!(s.faults.lost, 0, "slow tasks still drain");
    assert_eq!(s.latency.len() as u64, s.tasks);
}

#[test]
fn cloud_crash_degrades_doubtfuls_instead_of_stranding() {
    let cfg = small_cfg();
    let plan = FaultPlan {
        crashes: vec![crate::faults::CrashWindow { node: 0, from: 5.0, until: 100.0 }],
        ..FaultPlan::none()
    };
    let mut h = Harness::builder(cfg).mode(synth_mode()).plan(plan).build();
    let r = h.run(Scheme::SurveilEdge).unwrap();
    assert_eq!(r.faults.lost, 0, "no task may be stranded by the cloud outage");
    assert_eq!(r.latency.len() as u64, r.tasks);
    assert!(r.faults.degraded > 0, "cloud outage must force edge-local verdicts");
}

#[test]
fn builder_defaults_and_report_schema() {
    let h = Harness::builder(small_cfg()).build();
    assert!(matches!(h.mode, ComputeMode::Synthetic { .. }));
    assert!(h.plan.is_empty(), "default plan comes from cfg.faults (empty here)");
    assert!(h.obs.is_none());
    let mut h = Harness::builder(small_cfg()).mode(synth_mode()).build();
    let r = h.run(Scheme::SurveilEdge).unwrap();
    let rep = r.report();
    assert_eq!(rep.kind, "scheme_run");
    assert_eq!(rep.name, r.row.scheme);
    assert_eq!(rep.get("tasks"), Some(r.tasks as f64));
    assert_eq!(rep.get("faults_lost"), Some(0.0));
    assert!(rep.get("p99_latency_s").unwrap() >= rep.get("p50_latency_s").unwrap());
}

#[test]
fn observed_run_emits_spans_and_valid_exports() {
    let reg = Registry::new();
    let mut h =
        Harness::builder(small_cfg()).mode(synth_mode()).observe(reg.clone()).build();
    let r = h.run(Scheme::SurveilEdge).unwrap();
    assert!(reg.event_count() > 0, "an observed run must record spans");
    let sl = [("scheme", r.row.scheme.as_str())];
    assert_eq!(reg.counter("surveiledge_harness_tasks_total", &sl), r.tasks);
    assert_eq!(reg.counter("surveiledge_harness_uploads_total", &sl), r.uploads);
    crate::obs::validate_prometheus(&reg.export_prometheus()).unwrap();
    assert_eq!(
        crate::obs::validate_jsonl(&reg.export_jsonl()).unwrap(),
        reg.event_count()
    );
}

#[test]
fn run_spec_drives_selected_schemes() {
    let spec = RunSpec::new(small_cfg()).schemes(&[Scheme::SurveilEdge, Scheme::EdgeOnly]);
    let results = run_all_schemes(&spec).unwrap();
    assert_eq!(results.len(), 2);
    assert_ne!(results[0].row.scheme, results[1].row.scheme);
}

#[test]
fn finetune_corpus_shapes() {
    let (px, lb) = finetune_corpus(ClassId::Moped, 64, 3);
    assert_eq!(px.len(), 64 * 32 * 32 * 3);
    assert_eq!(lb.len(), 64);
    assert_eq!(lb.iter().filter(|&&l| l == 1).count(), 32);
}

/// ISSUE acceptance: the threaded `run_all_schemes` must reproduce a
/// plain sequential loop byte-for-byte at the same seed — the DES is
/// deterministic per scheme and the runs share no mutable state.
#[test]
fn parallel_run_matches_sequential_reports_byte_for_byte() {
    let cfg = small_cfg();
    let parallel = run_all_schemes(&RunSpec::new(cfg.clone())).unwrap();
    let sequential: Vec<SchemeResult> = Scheme::all()
        .into_iter()
        .map(|scheme| {
            Harness::builder(cfg.clone()).mode(synth_mode()).build().run(scheme).unwrap()
        })
        .collect();
    assert_eq!(parallel.len(), sequential.len());
    for (p, s) in parallel.iter().zip(&sequential) {
        assert_eq!(p.row.scheme, s.row.scheme, "spec order must be preserved");
        assert_eq!(
            p.report().to_json(),
            s.report().to_json(),
            "parallel vs sequential diverged for {}",
            p.row.scheme
        );
        assert_eq!(p.per_frame, s.per_frame, "per-frame traces must match for {}", p.row.scheme);
    }
}

/// Custom policies are first-class: a policy outside the four built-ins
/// runs through the same engine via `run_policy`.
#[test]
fn custom_policy_runs_through_the_engine() {
    struct AlwaysCloud;
    impl SchemePolicy for AlwaysCloud {
        fn scheme(&self) -> Scheme {
            Scheme::CloudOnly
        }
        fn name(&self) -> &'static str {
            "always-cloud"
        }
        fn route(&self, _ctx: &RouteCtx<'_>) -> crate::types::NodeId {
            crate::types::NodeId::CLOUD
        }
        fn falls_back_to_edge(&self) -> bool {
            false
        }
    }
    let cfg = small_cfg();
    let mut h = Harness::builder(cfg.clone()).mode(synth_mode()).build();
    let custom = h.run_policy(&AlwaysCloud).unwrap();
    assert_eq!(custom.row.scheme, "always-cloud");
    // Identical behavior to the built-in cloud-only, under its own label.
    let mut h2 = Harness::builder(cfg).mode(synth_mode()).build();
    let builtin = h2.run(Scheme::CloudOnly).unwrap();
    assert_eq!(custom.tasks, builtin.tasks);
    assert!((custom.row.accuracy - builtin.row.accuracy).abs() < 1e-12);
    assert!((custom.row.avg_latency - builtin.row.avg_latency).abs() < 1e-12);
}
