//! Shared per-task pipeline stages — one decision path for both
//! substrates.
//!
//! DESIGN.md §4 promises that the DES harness (logical time) and the live
//! thread workers (`nodes::EdgeWorker`/`CloudWorker`, wall time) drive the
//! *same* scheduler/controller code. This module is where that sharing
//! actually happens:
//!
//! * [`detect_crops`] — the detect stage: frame-difference detection over
//!   a 3-frame window, margin-expanded crops, best-IoU ground-truth match.
//! * [`classify_stage`] — the edge classify stage: eqs. 8–9 controller
//!   update from the substrate's congestion signal, the scheme's band
//!   decision, and the cloud-liveness fallback (upload vs graceful
//!   degradation).
//!
//! Substrate-specific inputs (what time it is, how congested the doubtful
//! path looks, whether the cloud heartbeat is fresh) come in through the
//! [`PipelineCtx`] trait; scheme-specific behavior through
//! [`SchemePolicy`](super::scheme::SchemePolicy). The engine's event loop
//! and `EdgeWorker::classify` are then just drivers around these calls.
//!
//! The compute modes live here too: classifications are either real PJRT
//! calls on the AOT artifacts (`--features pjrt`) or calibrated synthetic
//! confidences — both substrates consume them through [`ComputeMode`].

use crate::config::Config;
use crate::detect::{detect, DetectConfig};
use crate::overload::LoadLevel;
use crate::query::QuerySet;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, ModelRunner, MomentumSgd};
use crate::sched::{BandDecision, ThresholdController};
use crate::testkit::Rng;
use crate::trace::synth_confidence;
use crate::types::{BBox, ClassId, Image};

use super::scheme::SchemePolicy;

/// The hard confidence split used wherever an edge must answer without a
/// cloud re-check: edge-only's decision rule and the graceful-degradation
/// fallback while the cloud is dark.
pub const EDGE_SPLIT: f32 = 0.5;

/// Compute source for classifications.
pub enum ComputeMode {
    /// Real PJRT inference through the AOT bundle (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt(Box<PjrtCtx>),
    /// Calibrated synthetic confidences (no artifacts required).
    Synthetic {
        /// Edge CNN separability (higher = better CQ-CNN).
        sharpness: f64,
        /// Probability the edge CNN is *confidently wrong* (drawn as if
        /// the object were the other class) — models the calibration gap
        /// that gives the paper's edge-only its ~69% F2.
        edge_flip: f64,
        /// Probability the cloud oracle agrees with ground truth.
        oracle_acc: f64,
    },
}

impl ComputeMode {
    /// The calibrated synthetic mode every CLI/bench defaults to (matches
    /// the paper-era confidence calibration, DESIGN.md §3).
    pub fn synthetic_default() -> ComputeMode {
        ComputeMode::Synthetic { sharpness: 10.0, edge_flip: 0.15, oracle_acc: 0.99 }
    }

    /// Oracle answer + synthetic confidence for a new task: what the
    /// cloud CNN would say about `crop`, and (synthetic mode only) the
    /// edge confidence to replay at classify time.
    pub fn judge(
        &mut self,
        query: ClassId,
        crop: &Image,
        truth: Option<ClassId>,
        rng: &mut Rng,
    ) -> crate::Result<(bool, Option<f32>)> {
        let _ = crop; // only the PJRT arm consumes pixels
        #[cfg(feature = "pjrt")]
        if let ComputeMode::Pjrt(ctx) = self {
            let probs = ctx.cloud_model.infer(&crop.data)?;
            let best = probs[0]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(usize::MAX);
            return Ok((best == query.index(), None));
        }
        let (oracle, conf) = self.judge_shared(query, truth, rng);
        Ok((oracle, Some(conf)))
    }

    /// Oracle + confidence for a *derived* query class, without touching
    /// compute state: N queries derive per-class results from one shared
    /// detection. In synthetic mode this consumes the rng exactly like
    /// [`Self::judge`] (hard examples "flip" with diluted confidence —
    /// most land in the doubtful band where the cloud can rescue them,
    /// some are confidently wrong, matching the paper-era calibration of
    /// the CQ-CNN). The PJRT arm has no side-channel per-class output, so
    /// derived classes answer with the ground truth at split confidence.
    pub fn judge_shared(
        &self,
        query: ClassId,
        truth: Option<ClassId>,
        rng: &mut Rng,
    ) -> (bool, f32) {
        match self {
            #[cfg(feature = "pjrt")]
            ComputeMode::Pjrt(_) => (truth.is_some_and(|c| c == query), EDGE_SPLIT),
            ComputeMode::Synthetic { sharpness, edge_flip, oracle_acc } => {
                let truth_pos = truth.is_some_and(|c| c == query);
                let oracle = if rng.bool(*oracle_acc) { truth_pos } else { !truth_pos };
                let (seen_as, sharp) = if rng.bool(*edge_flip) {
                    (!truth_pos, (*sharpness / 3.0).max(1.0))
                } else {
                    (truth_pos, *sharpness)
                };
                (oracle, synth_confidence(rng, seen_as, sharp))
            }
        }
    }

    /// Edge CNN confidence at classify time: a real PJRT call on the
    /// stored crop pixels, or the precomputed synthetic draw.
    pub fn edge_confidence(&mut self, crop: &[f32], synth: Option<f32>) -> crate::Result<f32> {
        let _ = crop; // only the PJRT arm consumes pixels
        match self {
            #[cfg(feature = "pjrt")]
            ComputeMode::Pjrt(ctx) => {
                let probs = ctx.edge_model.infer(crop)?;
                Ok(probs[0].get(1).copied().unwrap_or(0.0))
            }
            ComputeMode::Synthetic { .. } => Ok(synth.unwrap_or(0.0)),
        }
    }
}

/// Standard mode selection shared by the binary, benches and examples:
/// PJRT when requested (requires the `pjrt` feature and artifacts, with 30
/// fine-tune steps), the calibrated synthetic mode otherwise.
pub fn standard_mode(cfg: &Config, pjrt: bool) -> crate::Result<ComputeMode> {
    let _ = cfg; // only consulted on the PJRT path
    if pjrt {
        #[cfg(feature = "pjrt")]
        return Ok(ComputeMode::Pjrt(Box::new(PjrtCtx::prepare(cfg, 30)?)));
        #[cfg(not(feature = "pjrt"))]
        anyhow::bail!(
            "--pjrt / BENCH_PJRT=1 needs a build with the runtime bridge: \
             cargo build --release --features pjrt (and `make artifacts`)"
        );
    }
    Ok(ComputeMode::synthetic_default())
}

/// PJRT context: engine + fine-tuned edge model + cloud model.
#[cfg(feature = "pjrt")]
pub struct PjrtCtx {
    pub engine: Engine,
    pub edge_model: ModelRunner,
    pub cloud_model: ModelRunner,
}

#[cfg(feature = "pjrt")]
impl PjrtCtx {
    /// Build the context: load the bundle and run the online fine-tuning
    /// stage (head-group momentum-SGD on a renderer-generated
    /// context dataset) so the deployed edge model is the CQ-specific CNN.
    pub fn prepare(cfg: &Config, finetune_steps: usize) -> crate::Result<PjrtCtx> {
        let engine = Engine::new(std::path::Path::new(&cfg.artifacts))?;
        let mut params = engine.edge_pretrained()?;
        if finetune_steps > 0 {
            let trainer = engine.trainer()?;
            let n = params.len();
            let mask = MomentumSgd::head_only_mask(n, engine.manifest.edge_head_group);
            let mut opt = MomentumSgd::new(&engine.manifest.edge_params, 0.005, mask);
            let (pixels, labels) = finetune_corpus(cfg.query, 256, cfg.seed ^ 0xF1);
            let batch = trainer.batch;
            let px = trainer.img * trainer.img * 3;
            let mut rng = Rng::new(cfg.seed ^ 0x7A);
            let mut bpix = vec![0.0f32; batch * px];
            let mut blab = vec![0i32; batch];
            for _ in 0..finetune_steps {
                for j in 0..batch {
                    let k = rng.range_usize(0, labels.len());
                    bpix[j * px..(j + 1) * px].copy_from_slice(&pixels[k * px..(k + 1) * px]);
                    blab[j] = labels[k];
                }
                let out = trainer.grad_step(&params, &bpix, &blab)?;
                opt.step(&mut params, &out.grads);
            }
        }
        let edge_model = engine.edge_model(1, &params)?;
        let cloud_model = engine.cloud_model(1, &engine.cloud_trained()?)?;
        Ok(PjrtCtx { engine, edge_model, cloud_model })
    }
}

/// Renderer-generated binary fine-tune corpus (query vs rest), balanced.
pub fn finetune_corpus(query: ClassId, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    use crate::video::sprite::{render_sprite, SpriteParams};
    let mut rng = Rng::new(seed);
    let mut pixels = Vec::with_capacity(n * 32 * 32 * 3);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let positive = i % 2 == 0;
        let cls = if positive {
            query
        } else {
            loop {
                let c = ClassId::from_index(rng.range_usize(0, 8)).unwrap();
                if c != query {
                    break c;
                }
            }
        };
        let sprite = render_sprite(&SpriteParams {
            cls,
            size: rng.range_usize(14, 31),
            base: [rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95)],
            accent: [rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95), rng.range_f32(0.15, 0.95)],
            bg: [0.42 + rng.range_f32(-0.08, 0.08), 0.45 + rng.range_f32(-0.08, 0.08), 0.42 + rng.range_f32(-0.08, 0.08)],
            rot: rng.range_f32(-0.35, 0.35),
            jx: rng.range_f32(-0.12, 0.12),
            jy: rng.range_f32(-0.12, 0.12),
            noise: rng.range_f32(0.02, 0.14),
            seed: rng.next_u32(),
        });
        pixels.extend_from_slice(&sprite.resize(32, 32).data);
        labels.push(positive as i32);
    }
    (pixels, labels)
}

/// What the classify stage needs to know about its substrate. The DES
/// engine answers from simulated queue state; the live `EdgeWorker`
/// answers from atomics, the parameter DB and wall-clock heartbeats.
pub trait PipelineCtx {
    /// eq. 8's l_d·t_d: the expected latency of the *re-classification
    /// path* a doubtful crop would take (uplink backlog + cloud queue).
    /// Fed to the controller as `update(1, signal)` to keep the eq. 8
    /// form.
    fn congestion_signal(&self) -> f64;

    /// Is the cloud reachable? `false` means a doubtful crop degrades to
    /// an edge-local verdict instead of queueing into a dead path.
    fn cloud_alive(&self) -> bool;

    /// Confidence split for a degraded (cloud-less) verdict.
    fn degrade_split(&self) -> f32 {
        EDGE_SPLIT
    }

    /// The admitted query set this substrate runs against. `None` (the
    /// default) is the classic single-implicit-query run — no fanout, no
    /// per-query weighting, byte-identical to pre-query builds.
    fn query_set(&self) -> Option<&QuerySet> {
        None
    }

    /// The degradation ladder's current response level for this edge
    /// (`crate::overload`). At `EdgeLocal` or above, doubtful crops stop
    /// uploading and answer at the edge even while the cloud is alive.
    /// The default (`Normal`) is the pre-overload behavior, so substrates
    /// without overload control are byte-identical.
    fn overload_level(&self) -> LoadLevel {
        LoadLevel::Normal
    }
}

/// What to do with a task after the edge classified it.
pub enum EdgeAction {
    /// Confidence cleared the band: answer at the edge.
    Verdict { positive: bool },
    /// Doubtful and the cloud is reachable: upload for re-classification.
    Upload,
    /// Doubtful but the cloud is dark: degrade to an edge-local verdict
    /// (§IV-D's latency/accuracy trade at its limit).
    Degrade { positive: bool },
}

/// Outcome of the shared classify stage: the raw band decision (span
/// detail, diagnostics) plus the action the substrate must carry out.
pub struct EdgeOutcome {
    pub decision: BandDecision,
    pub action: EdgeAction,
}

impl EdgeOutcome {
    /// Stable span-detail label for the band decision.
    pub fn band(&self) -> &'static str {
        self.decision.as_str()
    }
}

/// The edge classify stage both substrates run after inference:
/// controller update (eqs. 8–9) from the substrate's congestion signal,
/// the scheme's band decision, and the cloud-liveness fallback.
pub fn classify_stage(
    ctx: &dyn PipelineCtx,
    policy: &dyn SchemePolicy,
    controller: &mut ThresholdController,
    confidence: f32,
) -> EdgeOutcome {
    controller.update(1, ctx.congestion_signal());
    let decision = policy.decide(controller, confidence);
    let action = match decision {
        BandDecision::Positive | BandDecision::Negative => {
            EdgeAction::Verdict { positive: decision == BandDecision::Positive }
        }
        BandDecision::Doubtful => {
            // Upload only while the cloud is reachable AND the ladder has
            // not escalated to edge-local responses — a pressured uplink
            // degrades exactly like a dark cloud (PR 2's path).
            if ctx.cloud_alive() && ctx.overload_level() < LoadLevel::EdgeLocal {
                EdgeAction::Upload
            } else {
                EdgeAction::Degrade { positive: confidence >= ctx.degrade_split() }
            }
        }
    };
    EdgeOutcome { decision, action }
}

/// One detected crop with its ground-truth match — the output of the
/// shared detect stage.
pub struct DetectedCrop {
    /// The detector's bounding box (what IoU-matched the ground truth).
    pub bbox: BBox,
    /// Margin-expanded crop region (wire-size accounting uses its area).
    pub expanded: BBox,
    /// Crop at CNN input resolution, taken from the *middle* frame of the
    /// detection window.
    pub crop: Image,
    /// Ground-truth class by best-IoU match (> 0.2), if any.
    pub truth_cls: Option<ClassId>,
}

/// The detect stage both substrates run per camera tick: frame-difference
/// detection over the `(prev2, prev, cur)` window, margin-expanded crops
/// from the middle frame, and best-IoU ground-truth matching.
pub fn detect_crops(
    prev2: &Image,
    prev: &Image,
    cur: &Image,
    truth: &[(ClassId, BBox)],
    dcfg: &DetectConfig,
) -> Vec<DetectedCrop> {
    detect(prev2, prev, cur, dcfg)
        .into_iter()
        .map(|det| {
            let expanded = det.bbox.expand(dcfg.margin, cur.h, cur.w);
            let crop = prev
                .crop(expanded.y0, expanded.x0, expanded.y1, expanded.x1)
                .resize(dcfg.crop_size, dcfg.crop_size);
            // Ground truth by best-IoU match.
            let truth_cls = truth
                .iter()
                .map(|(c, tb)| (*c, det.bbox.iou(tb)))
                .filter(|(_, iou)| *iou > 0.2)
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(c, _)| c);
            DetectedCrop { bbox: det.bbox, expanded, crop, truth_cls }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::harness::scheme::policy_for;

    struct Scripted {
        signal: f64,
        cloud_alive: bool,
        level: LoadLevel,
    }

    impl Scripted {
        fn new(signal: f64, cloud_alive: bool) -> Scripted {
            Scripted { signal, cloud_alive, level: LoadLevel::Normal }
        }
    }

    impl PipelineCtx for Scripted {
        fn congestion_signal(&self) -> f64 {
            self.signal
        }
        fn cloud_alive(&self) -> bool {
            self.cloud_alive
        }
        fn overload_level(&self) -> LoadLevel {
            self.level
        }
    }

    #[test]
    fn classify_stage_maps_band_to_action() {
        let policy = policy_for(Scheme::SurveilEdge);
        let mut ctl = policy.controller(0.0, 0.25, 1.0); // γ₁=0: band stays [0.05, 0.8]
        let ctx = Scripted::new(0.0, true);
        let hi = classify_stage(&ctx, policy, &mut ctl, 0.95);
        assert!(matches!(hi.action, EdgeAction::Verdict { positive: true }));
        assert_eq!(hi.band(), "positive");
        let lo = classify_stage(&ctx, policy, &mut ctl, 0.01);
        assert!(matches!(lo.action, EdgeAction::Verdict { positive: false }));
        let mid = classify_stage(&ctx, policy, &mut ctl, 0.5);
        assert!(matches!(mid.action, EdgeAction::Upload));
        assert_eq!(mid.band(), "doubtful");
    }

    #[test]
    fn classify_stage_degrades_when_cloud_is_dark() {
        let policy = policy_for(Scheme::SurveilEdge);
        let mut ctl = policy.controller(0.0, 0.25, 1.0);
        let ctx = Scripted::new(0.0, false);
        let up = classify_stage(&ctx, policy, &mut ctl, 0.6);
        assert!(matches!(up.action, EdgeAction::Degrade { positive: true }));
        let down = classify_stage(&ctx, policy, &mut ctl, 0.4);
        assert!(matches!(down.action, EdgeAction::Degrade { positive: false }));
        // The decision itself is still "doubtful" — only the action
        // changes.
        assert_eq!(up.band(), "doubtful");
    }

    #[test]
    fn classify_stage_updates_the_controller_before_deciding() {
        let policy = policy_for(Scheme::SurveilEdge);
        let mut ctl = policy.controller(0.1, 0.25, 1.0);
        let a0 = ctl.alpha;
        // A heavily congested doubtful path must narrow the band on the
        // very call that decides.
        let ctx = Scripted::new(50.0, true);
        let _ = classify_stage(&ctx, policy, &mut ctl, 0.7);
        assert!(ctl.alpha < a0, "congestion must pull α down ({} -> {})", a0, ctl.alpha);
    }

    #[test]
    fn edge_only_never_uploads_through_the_stage() {
        let policy = policy_for(Scheme::EdgeOnly);
        let mut ctl = policy.controller(0.1, 0.25, 1.0);
        let ctx = Scripted::new(0.0, true);
        for conf in [0.0f32, 0.3, 0.5, 0.7, 1.0] {
            let out = classify_stage(&ctx, policy, &mut ctl, conf);
            assert!(
                matches!(out.action, EdgeAction::Verdict { .. }),
                "edge-only must answer locally at confidence {conf}"
            );
        }
    }

    #[test]
    fn classify_stage_degrades_at_edge_local_ladder_level() {
        let policy = policy_for(Scheme::SurveilEdge);
        let mut ctl = policy.controller(0.0, 0.25, 1.0);
        // Cloud alive, but the ladder escalated to edge-local verdicts:
        // doubtful crops must degrade instead of uploading.
        let mut ctx = Scripted::new(0.0, true);
        ctx.level = LoadLevel::EdgeLocal;
        let out = classify_stage(&ctx, policy, &mut ctl, 0.6);
        assert!(matches!(out.action, EdgeAction::Degrade { positive: true }));
        ctx.level = LoadLevel::Shed;
        let out = classify_stage(&ctx, policy, &mut ctl, 0.4);
        assert!(matches!(out.action, EdgeAction::Degrade { positive: false }));
        // Subsample is below the edge-local rung: uploads still flow.
        ctx.level = LoadLevel::Subsample;
        let out = classify_stage(&ctx, policy, &mut ctl, 0.5);
        assert!(matches!(out.action, EdgeAction::Upload));
    }

    #[test]
    fn judge_and_judge_shared_agree_at_the_same_seed() {
        // The engine calls `judge` for the primary query and
        // `judge_shared` for derived ones; both must draw the same
        // (oracle, confidence) stream or work sharing would skew results.
        let mut mode = ComputeMode::synthetic_default();
        for seed in [1u64, 7, 99] {
            for (query, truth) in
                [(ClassId::Moped, Some(ClassId::Moped)), (ClassId::Person, Some(ClassId::Car)), (ClassId::Car, None)]
            {
                let crop = Image::new(2, 2);
                let mut r1 = crate::testkit::Rng::new(seed);
                let mut r2 = crate::testkit::Rng::new(seed);
                let (o1, c1) = mode.judge(query, &crop, truth, &mut r1).unwrap();
                let (o2, c2) = mode.judge_shared(query, truth, &mut r2);
                assert_eq!(o1, o2);
                assert_eq!(c1, Some(c2));
                assert_eq!(r1.next_u64(), r2.next_u64(), "rng streams diverged");
            }
        }
    }

    #[test]
    fn pipeline_ctx_default_has_no_query_set() {
        let ctx = Scripted::new(0.0, true);
        assert!(ctx.query_set().is_none());
    }

    #[test]
    fn detect_crops_emits_cnn_sized_crops_with_truth() {
        use crate::video::standard_deployment;
        let mut cams = standard_deployment(1, 48, 64, 7);
        let dcfg = DetectConfig::default();
        let f0 = cams[0].frame_at(1.0).image;
        let f1 = cams[0].frame_at(2.0).image;
        let f2 = cams[0].frame_at(3.0).image;
        let truth = cams[0].truth_at(3.0);
        let crops = detect_crops(&f0, &f1, &f2, &truth, &dcfg);
        for c in &crops {
            assert_eq!((c.crop.h, c.crop.w), (dcfg.crop_size, dcfg.crop_size));
            assert!(c.expanded.area() >= c.bbox.area());
        }
    }
}
