//! Pure DES mechanics: the event heap, node/uplink queue state, fault and
//! heartbeat scheduling, the drain horizon — and the event loop that
//! drives one scheme run.
//!
//! The engine knows nothing about the four schemes. Every point where
//! they diverge (controller construction, routing, band decision, retry
//! fallback, the failover sweep) goes through the
//! [`SchemePolicy`](super::scheme::SchemePolicy) it is handed, and the
//! per-task classify logic is the shared stage layer in
//! [`pipeline`](super::pipeline) — the same code `nodes::EdgeWorker`
//! runs on the live substrate.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::estimator::LatencyEstimator;
use crate::faults::{backoff, unit_hash, FaultPlan, HB_INTERVAL, HB_STALE_AFTER, MAX_DISPATCH_ATTEMPTS};
use crate::metrics::{Confusion, FaultStats, LatencyRecorder, SchemeRow};
use crate::nodes::node_alive;
use crate::obs::{node_label, Stage};
use crate::overload::{
    shed_victim, CircuitBreaker, DegradationLadder, LoadLevel, OverloadConfig, Transition,
};
use crate::paramdb::{ParamDb, Value};
use crate::query::{DeadlineClass, QuerySet, QueryVerdict, TaskQueryView};
use crate::sched::{NodeLoad, ThresholdController};
use crate::testkit::Rng;
use crate::types::{CameraId, Image, NodeId};
use crate::video::standard_deployment;

use crate::detect::DetectConfig;

use super::pipeline::{self, ComputeMode, EdgeAction, PipelineCtx};
use super::scheme::{RouteCtx, SchemePolicy};
use super::{EdgeOutage, Harness, SchemeResult, ServiceTimes, HD_SCALE};

/// One task flowing through the DES.
#[derive(Clone)]
pub(crate) struct SimTask {
    pub(crate) id: u64,
    pub(crate) t_capture: f64,
    pub(crate) home_edge: u32,
    /// When the task last entered a queue (node or uplink) — feeds the
    /// queue/uplink stage spans.
    pub(crate) t_enqueue: f64,
    /// Crop pixels (PJRT mode) — empty in synthetic mode.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    pub(crate) crop: Vec<f32>,
    pub(crate) wire_bytes: u64,
    pub(crate) truth_positive: Option<bool>,
    /// Precomputed oracle answer (what the cloud CNN says).
    pub(crate) oracle_positive: bool,
    /// Precomputed edge confidence (synthetic mode) or None (PJRT).
    pub(crate) synth_confidence: Option<f32>,
    /// Delivery attempts so far (fault runs: drop / no-ack retries).
    pub(crate) attempt: u32,
    /// Set once an edge classified it doubtful — from then on its
    /// destination is pinned to the cloud re-check path.
    pub(crate) doubtful: bool,
    /// Per-query views of this shared task (empty without a query set):
    /// the detect + edge-infer work runs once; these only fan out the
    /// threshold decision at verdict time.
    pub(crate) qviews: Vec<TaskQueryView>,
    /// eq. 7 deadline weight of the most demanding query covering this
    /// task's camera at capture (1.0 without a query set).
    pub(crate) route_weight: f64,
    /// Deadline class of the most demanding covering query at capture
    /// (`Standard` without a query set) — what the overload shed policy
    /// protects: batch sheds first, interactive last.
    pub(crate) class: DeadlineClass,
}

/// DES events.
pub(crate) enum Event {
    /// Sample all cameras of all edges at this tick.
    Sample,
    /// A node finished its current classification.
    NodeFinish { node: u32 },
    /// An uplink finished its current transfer.
    UplinkFinish { edge: u32 },
    /// A failed edge comes back and resumes its queue.
    NodeResume { node: u32 },
    /// Heartbeat tick: every live node publishes `hb/<id>` (fault runs
    /// only — fault-free runs never schedule this).
    Heartbeat,
    /// Scripted fault-plan transitions.
    FaultCrash { node: u32 },
    FaultRecover { node: u32 },
    /// Stale-heartbeat detection point after a crash: sweep the dead
    /// node's stranded queue back through the allocator.
    Failover { node: u32, crash_from: f64 },
    /// Ack-timeout backoff expired: re-dispatch a task whose delivery
    /// failed.
    Redispatch { task: SimTask },
}

/// Min-heap key: event time, then scheduling sequence number.
///
/// The hand-rolled `Ord`/`Eq` give f64 times a total order. Event times
/// are finite by construction — [`Des::schedule`] asserts it — so the
/// `partial_cmp(..).unwrap_or(Equal)` NaN fallback is never exercised,
/// and the `seq` tie-break keeps same-time events in scheduling order.
pub(crate) struct HeapKey(pub(crate) f64, pub(crate) u64);

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0 && self.1 == other.1
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.1.cmp(&other.1))
    }
}

/// Per-node (edge or cloud) queue state.
pub struct NodeSim {
    pub(crate) queue: VecDeque<SimTask>,
    pub(crate) busy: bool,
    pub(crate) estimator: LatencyEstimator,
    pub(crate) speed: f64,
    /// Pending NodeFinish event id — cancelled when the node crashes.
    pub(crate) finish_ev: Option<u64>,
}

impl NodeSim {
    /// The allocator's view of this node (eq. 7 candidate).
    pub fn load(&self, id: u32, penalty: f64) -> NodeLoad {
        NodeLoad {
            node: NodeId(id),
            queue: self.queue.len() + self.busy as usize,
            t_infer: self.estimator.estimate(),
            penalty,
        }
    }
}

/// Per-edge uplink state.
pub struct Uplink {
    pub(crate) queue: VecDeque<SimTask>,
    pub(crate) busy: bool,
    /// Bytes waiting (including the in-flight transfer) — feeds the
    /// controller's congestion signal and the allocator's cloud penalty.
    pub(crate) queued_bytes: u64,
}

impl Uplink {
    /// Bytes waiting on this link (including the in-flight transfer).
    pub fn queued_bytes(&self) -> u64 {
        self.queued_bytes
    }
}

pub(crate) fn service_time(node: u32, sim: &NodeSim, times: &ServiceTimes) -> f64 {
    if node == 0 {
        times.cloud_infer / sim.speed
    } else {
        times.edge_infer / sim.speed
    }
}

type EventHeap = BinaryHeap<Reverse<(HeapKey, u8)>>;
type EventMap = HashMap<u64, Event>;

/// Immutable fault context for one scheme run.
pub(crate) struct FaultCtx {
    pub(crate) plan: FaultPlan,
    pub(crate) outage: Option<EdgeOutage>,
}

/// Mutable discrete-event state for one scheme run, bundled so the
/// dispatch / retry / failover paths share one signature.
pub(crate) struct Des {
    pub(crate) nodes: Vec<NodeSim>,
    pub(crate) uplinks: Vec<Uplink>,
    pub(crate) heap: EventHeap,
    pub(crate) events: EventMap,
    pub(crate) seq: u64,
    /// Bytes shipped over any uplink (bandwidth accounting).
    pub(crate) cloud_bytes: u64,
    pub(crate) fstats: FaultStats,
    pub(crate) times: ServiceTimes,
    pub(crate) uplink_bps: f64,
    pub(crate) fx: FaultCtx,
    /// Overload control (`[overload]`). `enabled == false` gates every
    /// consumer below, so a config without the block replays the exact
    /// event/RNG/metric sequence it always had.
    pub(crate) ocfg: OverloadConfig,
    /// Per-uplink circuit breakers (index 0 = edge 1's uplink).
    pub(crate) breakers: Vec<CircuitBreaker>,
    /// Per-edge degradation ladders.
    pub(crate) ladders: Vec<DegradationLadder>,
    /// In-flight ack-timeout retries per home edge (the bounded retry
    /// budget that keeps a slow-node window from becoming a retry storm).
    pub(crate) retry_inflight: Vec<u32>,
    /// Deepest node queue observed (overload runs only; exported as a
    /// gauge for the retry-budget regression test).
    pub(crate) max_depth: u64,
}

impl Des {
    /// Schedule `ev` at time `t`; the returned id cancels it via
    /// `events.remove` (the heap entry then no-ops). Finite times are an
    /// invariant here — a NaN key would silently corrupt the heap order.
    pub(crate) fn schedule(&mut self, t: f64, ev: Event) -> u64 {
        assert!(t.is_finite(), "event time must be finite, got {t}");
        let id = self.seq;
        self.events.insert(id, ev);
        self.heap.push(Reverse((HeapKey(t, id), 0)));
        self.seq += 1;
        id
    }

    pub(crate) fn enqueue_node(&mut self, n: usize, mut task: SimTask, t: f64) {
        task.t_enqueue = t;
        self.nodes[n].queue.push_back(task);
        self.start_if_idle(n, t);
    }

    pub(crate) fn start_if_idle(&mut self, n: usize, t: f64) {
        if self.nodes[n].busy || self.nodes[n].queue.is_empty() {
            return;
        }
        // Legacy outage: a dead edge holds its queue until recovery
        // (cloud never fails on this path).
        if let Some(o) = self.fx.outage {
            if n > 0 && o.covers(t, n as u32) {
                self.nodes[n].busy = true; // freeze; resume event at recovery
                self.schedule(o.until, Event::NodeResume { node: n as u32 });
                return;
            }
        }
        // Fault-plan crash: the queue is frozen but the node is not
        // marked busy — FaultRecover (or the failover sweep) picks the
        // tasks back up.
        if self.fx.plan.is_down(n as u32, t) {
            return;
        }
        self.nodes[n].busy = true;
        let service =
            service_time(n as u32, &self.nodes[n], &self.times) * self.fx.plan.slowdown(n as u32, t);
        let id = self.schedule(t + service, Event::NodeFinish { node: n as u32 });
        self.nodes[n].finish_ev = Some(id);
    }

    /// Queue a task on an edge's uplink toward the cloud (a retry
    /// retransmits, so the bytes count again).
    pub(crate) fn push_uplink(&mut self, e: usize, mut task: SimTask, t: f64) {
        task.t_enqueue = t;
        self.cloud_bytes += task.wire_bytes;
        self.uplinks[e].queued_bytes += task.wire_bytes;
        self.uplinks[e].queue.push_back(task);
        self.kick_uplink(e, t);
    }

    pub(crate) fn kick_uplink(&mut self, e: usize, t: f64) {
        if !self.uplinks[e].busy {
            if let Some(front) = self.uplinks[e].queue.front() {
                self.uplinks[e].busy = true;
                let transfer = front.wire_bytes as f64 / self.uplink_bps.max(1.0);
                self.schedule(t + transfer, Event::UplinkFinish { edge: e as u32 });
            }
        }
    }
}

/// The DES's view of the shared classify stage, captured at the moment an
/// edge finishes inference.
struct DesCtx<'a> {
    /// eq. 8 signal: uplink backlog drain + cloud queue + rtt.
    signal: f64,
    cloud_alive: bool,
    /// Attached query set (the engine fans verdicts out itself, but the
    /// stage layer exposes the same view both substrates see).
    queries: Option<&'a QuerySet>,
    /// This edge's degradation-ladder level (`Normal` without an
    /// `[overload]` block — the stage layer's default behavior).
    level: LoadLevel,
}

impl PipelineCtx for DesCtx<'_> {
    fn congestion_signal(&self) -> f64 {
        self.signal
    }
    fn cloud_alive(&self) -> bool {
        self.cloud_alive
    }
    fn query_set(&self) -> Option<&QuerySet> {
        self.queries
    }
    fn overload_level(&self) -> LoadLevel {
        self.level
    }
}

fn confidence_of(h: &mut Harness, task: &SimTask) -> crate::Result<f32> {
    h.mode.edge_confidence(&task.crop, task.synth_confidence)
}

#[allow(clippy::too_many_arguments)]
fn route_task(
    h: &Harness,
    policy: &dyn SchemePolicy,
    home: u32,
    t: f64,
    des: &Des,
    db: &ParamDb,
    route_weight: f64,
    cloud_uplink_open: bool,
) -> NodeId {
    policy.route(&RouteCtx {
        home,
        t,
        cfg: &h.cfg,
        nodes: &des.nodes,
        uplinks: &des.uplinks,
        db,
        outage: h.outage,
        obs: h.obs.as_ref(),
        route_weight,
        cloud_uplink_open,
    })
}

/// Record a circuit-breaker transition: a span (`circuit_open` /
/// `circuit_probe` / `circuit_close`) plus the matching counter.
fn breaker_transition(
    h: &Harness,
    name: &str,
    e: usize,
    t: f64,
    task: u64,
    tr: Option<Transition>,
) {
    let Some(tr) = tr else { return };
    let (stage, counter) = match tr {
        Transition::Opened => (Stage::CircuitOpen, "surveiledge_overload_trips_total"),
        Transition::HalfOpened => (Stage::CircuitProbe, "surveiledge_overload_probes_total"),
        Transition::Closed => (Stage::CircuitClose, "surveiledge_overload_closes_total"),
    };
    h.span(name, t, task, stage, e as u32 + 1, 0.0, "");
    if let Some(reg) = &h.obs {
        let el = node_label(e as u32 + 1);
        reg.inc(counter, &[("scheme", name), ("edge", el.as_str())], 1);
    }
}

/// Is `home`'s uplink breaker refusing traffic right now? Polls the
/// breaker (an elapsed dwell half-opens here) and records any transition.
/// Always `false` without an `[overload]` block.
fn breaker_open(h: &Harness, name: &str, des: &mut Des, home: u32, t: f64, task: u64) -> bool {
    if !des.ocfg.enabled {
        return false;
    }
    let e = (home - 1) as usize;
    let (ok, tr) = des.breakers[e].allows(t);
    breaker_transition(h, name, e, t, task, tr);
    !ok
}

/// Explicitly drop a task under overload control. Never silent: the shed
/// is counted in [`FaultStats`], spanned, and fanned out to every
/// covering query as a `site = "shed"` accounting record (always
/// negative, so precision/recall see a miss rather than nothing).
fn shed_finish(
    h: &Harness,
    name: &str,
    task: &SimTask,
    t: f64,
    des: &mut Des,
    result: &mut SchemeResult,
    reason: &'static str,
) {
    des.fstats.shed += 1;
    h.span(name, t, task.id, Stage::Shed, task.home_edge, (t - task.t_capture).max(0.0), reason);
    if let Some(reg) = &h.obs {
        reg.inc(
            "surveiledge_overload_shed_total",
            &[("scheme", name), ("class", task.class.as_str()), ("reason", reason)],
            1,
        );
    }
    if let Some(qs) = &h.queries {
        for v in &task.qviews {
            let spec = &qs.specs()[v.query];
            let qv = QueryVerdict {
                query: spec.id.clone(),
                task: task.id,
                t,
                positive: false,
                confidence: v.confidence,
                site: "shed",
                latency: (t - task.t_capture).max(0.0),
            };
            if let Some(reg) = &h.obs {
                reg.inc(
                    "surveiledge_query_verdicts_total",
                    &[("query", &spec.id), ("scheme", name), ("site", "shed")],
                    1,
                );
            }
            qs.publish_result(&qv);
            result.query_verdicts.push(qv);
        }
    }
}

/// Admit `task` to node `n`'s bounded queue. On overflow the
/// deadline-class shed policy picks a victim — batch first, then
/// standard, youngest first; the in-service front is untouchable — or
/// sheds the incoming task itself when nothing queued is cheaper.
/// Unbounded (the original `enqueue_node`) without `[overload]`.
fn enqueue_node_bounded(
    h: &Harness,
    name: &str,
    des: &mut Des,
    n: usize,
    task: SimTask,
    t: f64,
    result: &mut SchemeResult,
) {
    let cap = des.ocfg.node_queue_cap;
    if des.ocfg.enabled && cap > 0 && des.nodes[n].queue.len() >= cap {
        let start = des.nodes[n].busy as usize;
        let classes: Vec<DeadlineClass> = des.nodes[n].queue.iter().map(|q| q.class).collect();
        match shed_victim(&classes, start, task.class) {
            Some(i) => {
                let victim = des.nodes[n].queue.remove(i).expect("victim index in range");
                shed_finish(h, name, &victim, t, des, result, "queue_full");
                des.enqueue_node(n, task, t);
            }
            None => shed_finish(h, name, &task, t, des, result, "queue_full"),
        }
    } else {
        des.enqueue_node(n, task, t);
    }
    if des.ocfg.enabled {
        des.max_depth = des.max_depth.max(des.nodes[n].queue.len() as u64);
    }
}

/// Admit `task` to edge `e`'s bounded uplink queue. Overflow is both a
/// shed (same class policy as nodes) and a breaker failure signal — a
/// persistently saturated uplink trips open, and routing stops feeding
/// it. Unbounded without `[overload]`.
fn push_uplink_bounded(
    h: &Harness,
    name: &str,
    des: &mut Des,
    e: usize,
    task: SimTask,
    t: f64,
    result: &mut SchemeResult,
) {
    let cap = des.ocfg.uplink_queue_cap;
    if des.ocfg.enabled && cap > 0 && des.uplinks[e].queue.len() >= cap {
        let tr = des.breakers[e].on_failure(t);
        breaker_transition(h, name, e, t, task.id, tr);
        let start = des.uplinks[e].busy as usize;
        let classes: Vec<DeadlineClass> = des.uplinks[e].queue.iter().map(|q| q.class).collect();
        match shed_victim(&classes, start, task.class) {
            Some(i) => {
                let victim = des.uplinks[e].queue.remove(i).expect("victim index in range");
                des.uplinks[e].queued_bytes =
                    des.uplinks[e].queued_bytes.saturating_sub(victim.wire_bytes);
                // The victim never crosses the wire: unwind its bytes
                // from the bandwidth accounting `push_uplink` charged.
                des.cloud_bytes = des.cloud_bytes.saturating_sub(victim.wire_bytes);
                shed_finish(h, name, &victim, t, des, result, "uplink_full");
                des.push_uplink(e, task, t);
            }
            None => shed_finish(h, name, &task, t, des, result, "uplink_full"),
        }
    } else {
        des.push_uplink(e, task, t);
    }
}

/// Send `task` toward `dest` (as chosen by the policy's route). Under a
/// fault plan a remote hop can fail — a dropped message or a dead
/// destination goes to the retry path instead of a queue.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    h: &mut Harness,
    policy: &dyn SchemePolicy,
    task: SimTask,
    dest: NodeId,
    t: f64,
    des: &mut Des,
    db: &ParamDb,
    result: &mut SchemeResult,
) -> crate::Result<()> {
    let home = task.home_edge;
    if dest.is_cloud() {
        // Uplink transfer; transit faults apply at delivery time.
        push_uplink_bounded(h, policy.name(), des, (home - 1) as usize, task, t, result);
    } else if dest.0 != home
        && (des.fx.plan.drops(task.id, task.attempt) || des.fx.plan.is_down(dest.0, t))
    {
        // Edge-to-edge hop lost (or the peer just died): no ack.
        retry_or_degrade(h, policy, task, t, des, db, result)?;
    } else {
        let delay = if dest.0 != home { des.fx.plan.delay_of(task.id) } else { 0.0 };
        enqueue_node_bounded(h, policy.name(), des, dest.0 as usize, task, t + delay, result);
    }
    Ok(())
}

/// A delivery failed: count the retry, back off exponentially, and
/// re-dispatch — or give up gracefully once the attempt budget is spent
/// or the cloud is known dead.
fn retry_or_degrade(
    h: &mut Harness,
    policy: &dyn SchemePolicy,
    mut task: SimTask,
    t: f64,
    des: &mut Des,
    db: &ParamDb,
    result: &mut SchemeResult,
) -> crate::Result<()> {
    des.fstats.retried += 1;
    h.span(policy.name(), t, task.id, Stage::Retry, task.home_edge, 0.0, "");
    let attempt = task.attempt;
    task.attempt += 1;
    // Cloud-only has no edge fallback: it keeps retrying (bounded
    // backoff) until the cloud answers.
    if policy.falls_back_to_edge() {
        let cloud_dead = task.doubtful && !node_alive(db, 0, t);
        if cloud_dead || task.attempt >= MAX_DISPATCH_ATTEMPTS {
            if task.doubtful {
                // §IV-D's latency/accuracy trade at its limit: an edge
                // verdict now beats a cloud verdict never.
                return degrade_finish(h, policy, task, t, des, result);
            }
            // Unclassified task: fall back to local processing.
            let home = task.home_edge as usize;
            enqueue_node_bounded(h, policy.name(), des, home, task, t, result);
            return Ok(());
        }
    }
    // Bounded retry budget: a slow-node window must not multiply into a
    // retry storm. Once this home edge has `retry_budget` re-dispatches
    // in flight, give up gracefully instead of queueing another.
    if des.ocfg.enabled && des.ocfg.retry_budget > 0 {
        let e = (task.home_edge - 1) as usize;
        if des.retry_inflight[e] >= des.ocfg.retry_budget {
            if policy.falls_back_to_edge() {
                if task.doubtful {
                    return degrade_finish(h, policy, task, t, des, result);
                }
                let home = task.home_edge as usize;
                enqueue_node_bounded(h, policy.name(), des, home, task, t, result);
                return Ok(());
            }
            shed_finish(h, policy.name(), &task, t, des, result, "retry_budget");
            return Ok(());
        }
        des.retry_inflight[e] += 1;
    }
    des.schedule(t + backoff(attempt), Event::Redispatch { task });
    Ok(())
}

/// Edge-local verdict without the cloud re-check (graceful degradation
/// when the cloud path is unavailable).
fn degrade_finish(
    h: &mut Harness,
    policy: &dyn SchemePolicy,
    task: SimTask,
    t: f64,
    des: &mut Des,
    result: &mut SchemeResult,
) -> crate::Result<()> {
    des.fstats.degraded += 1;
    h.span(policy.name(), t, task.id, Stage::Degrade, task.home_edge, 0.0, "");
    let conf = confidence_of(h, &task)?;
    finish(
        h,
        result,
        policy.name(),
        &task,
        conf >= pipeline::EDGE_SPLIT,
        t - task.t_capture,
        t,
        "degraded",
    );
    Ok(())
}

/// Record a final verdict: metrics, the per-frame trace, the
/// end-of-pipeline span (`dur` = end-to-end latency) and the verdict
/// counter by site (`edge` / `cloud` / `degraded`) — then fan the
/// per-query threshold decisions out from this one shared result.
#[allow(clippy::too_many_arguments)]
fn finish(
    h: &Harness,
    result: &mut SchemeResult,
    name: &str,
    task: &SimTask,
    positive: bool,
    latency: f64,
    t: f64,
    site: &'static str,
) {
    result.vs_oracle.record(positive, task.oracle_positive);
    if let Some(tr) = task.truth_positive {
        result.vs_truth.record(positive, tr);
    }
    result.latency.record(latency);
    result.per_frame.push((t, latency, task.home_edge));
    h.span(name, t, task.id, Stage::Verdict, task.home_edge, latency, site);
    if let Some(reg) = &h.obs {
        reg.inc("surveiledge_harness_verdicts_total", &[("scheme", name), ("site", site)], 1);
    }
    // Work sharing: detect + edge inference ran once for this task; each
    // query only re-thresholds the shared per-class result. A query may
    // adopt the cloud's answer only if the *shared* task paid the upload.
    if let Some(qs) = &h.queries {
        let shared_cloud = site == "cloud";
        for v in &task.qviews {
            let spec = &qs.specs()[v.query];
            let (qpos, qsite) = spec.decide(v.confidence, v.oracle, shared_cloud);
            let qv = QueryVerdict {
                query: spec.id.clone(),
                task: task.id,
                t,
                positive: qpos,
                confidence: v.confidence,
                site: qsite,
                latency,
            };
            if let Some(reg) = &h.obs {
                reg.inc(
                    "surveiledge_query_verdicts_total",
                    &[("query", &spec.id), ("scheme", name), ("site", qsite)],
                    1,
                );
                reg.observe(
                    "surveiledge_query_latency_seconds",
                    &[("query", &spec.id), ("scheme", name)],
                    latency.max(0.0),
                );
            }
            qs.publish_result(&qv);
            result.query_verdicts.push(qv);
        }
    }
}

/// Run one scheme over the configured scenario — the DES event loop.
pub(crate) fn run_scheme(h: &mut Harness, policy: &dyn SchemePolicy) -> crate::Result<SchemeResult> {
    let cfg = h.cfg.clone();
    let name = policy.name();
    let n_edges = cfg.edges.len() as u32;
    let (frame_h, frame_w) = match &h.mode {
        #[cfg(feature = "pjrt")]
        ComputeMode::Pjrt(ctx) => (ctx.engine.manifest.frame_h, ctx.engine.manifest.frame_w),
        ComputeMode::Synthetic { .. } => (cfg.frame_h, cfg.frame_w),
    };

    // Cameras, assigned to edges in blocks.
    let mut cameras = standard_deployment(cfg.total_cameras() as usize, frame_h, frame_w, cfg.seed);
    let mut cam_edge: Vec<u32> = Vec::new();
    for (ei, e) in cfg.edges.iter().enumerate() {
        for _ in 0..e.cameras {
            cam_edge.push(ei as u32 + 1);
        }
    }

    // Node 0 = cloud; 1..=n = edges.
    let mut nodes: Vec<NodeSim> = Vec::new();
    nodes.push(NodeSim {
        queue: VecDeque::new(),
        busy: false,
        estimator: LatencyEstimator::new(h.times.cloud_infer),
        speed: cfg.cloud_speed,
        finish_ev: None,
    });
    for e in &cfg.edges {
        nodes.push(NodeSim {
            queue: VecDeque::new(),
            busy: false,
            estimator: LatencyEstimator::new(h.times.edge_infer / e.speed),
            speed: e.speed,
            finish_ev: None,
        });
    }
    let uplinks: Vec<Uplink> = (0..n_edges)
        .map(|_| Uplink { queue: VecDeque::new(), busy: false, queued_bytes: 0 })
        .collect();
    let mut controllers: Vec<ThresholdController> = (0..n_edges)
        .map(|_| policy.controller(cfg.gamma1, cfg.gamma2, cfg.interval))
        .collect();

    // Detection state per camera: previous two sampled frames.
    let mut prev_frames: Vec<Option<(Image, Image)>> = vec![None; cameras.len()];
    let detect_cfg = DetectConfig::default();
    let uplink_bps = cfg.uplink_mbps * 1_000_000.0 / 8.0;

    let mut des = Des {
        nodes,
        uplinks,
        heap: BinaryHeap::new(),
        events: HashMap::new(),
        seq: 0,
        cloud_bytes: 0,
        fstats: FaultStats::default(),
        times: h.times,
        uplink_bps,
        fx: FaultCtx { plan: h.plan.clone(), outage: h.outage },
        ocfg: cfg.overload.clone(),
        breakers: (0..n_edges).map(|_| CircuitBreaker::new(cfg.overload.breaker)).collect(),
        ladders: (0..n_edges).map(|_| DegradationLadder::new(cfg.overload.ladder)).collect(),
        retry_inflight: vec![0; n_edges as usize],
        max_depth: 0,
    };
    des.schedule(cfg.interval, Event::Sample);
    // Heartbeats + scripted crash transitions only exist under a
    // non-empty plan, so fault-free runs replay the exact event sequence
    // they always had.
    let faulty = !des.fx.plan.is_empty();
    let db = ParamDb::new();
    if let Some(reg) = &h.obs {
        // Heartbeat puts flow through the paramdb counter wiring; the
        // fault plan's shape lands as gauges so an export is
        // self-describing.
        db.attach_registry(reg.clone());
        if faulty {
            h.plan.export_into(reg, &[("scheme", name)]);
        }
    }
    // Drain horizon: keep serving queued tasks after the last sample.
    let drain_until = cfg.duration + 60.0;
    if faulty {
        des.schedule(0.0, Event::Heartbeat);
        for c in des.fx.plan.crashes.clone() {
            if c.until > c.from {
                des.schedule(c.from, Event::FaultCrash { node: c.node });
                des.schedule(c.until, Event::FaultRecover { node: c.node });
                if policy.schedules_failover_sweep() {
                    des.schedule(
                        c.from + HB_STALE_AFTER,
                        Event::Failover { node: c.node, crash_from: c.from },
                    );
                }
            }
        }
    }

    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut next_task_id = 0u64;
    let mut result = SchemeResult {
        row: SchemeRow {
            scheme: name.to_string(),
            accuracy: 0.0,
            avg_latency: 0.0,
            bandwidth_mb: 0.0,
        },
        latency: LatencyRecorder::new(),
        per_frame: Vec::new(),
        vs_oracle: Confusion::default(),
        vs_truth: Confusion::default(),
        uploads: 0,
        tasks: 0,
        mean_band_width: 0.0,
        faults: FaultStats::default(),
        query_verdicts: Vec::new(),
        per_query: Vec::new(),
    };
    let mut band_width_acc = 0.0f64;
    let mut band_width_n = 0u64;

    while let Some(Reverse((HeapKey(t, id), _))) = des.heap.pop() {
        if t > drain_until {
            break;
        }
        // A missing slot is a cancelled event (a crash cancels the
        // victim's in-flight completion).
        let Some(ev) = des.events.remove(&id) else { continue };
        match ev {
            Event::Sample => {
                if t + cfg.interval <= cfg.duration {
                    des.schedule(t + cfg.interval, Event::Sample);
                }
                // Overload: refresh each edge's ladder from its queue
                // pressure (worst of node occupancy and uplink occupancy
                // against the configured caps) before admitting this
                // tick's detections.
                if des.ocfg.enabled {
                    for e in 0..n_edges as usize {
                        let node_q = des.nodes[e + 1].queue.len();
                        let up_q = des.uplinks[e].queue.len();
                        let node_occ = if des.ocfg.node_queue_cap > 0 {
                            node_q as f64 / des.ocfg.node_queue_cap as f64
                        } else {
                            0.0
                        };
                        let up_occ = if des.ocfg.uplink_queue_cap > 0 {
                            up_q as f64 / des.ocfg.uplink_queue_cap as f64
                        } else {
                            0.0
                        };
                        let pressure = node_occ.max(up_occ);
                        des.ladders[e].observe(pressure, t);
                        if let Some(reg) = &h.obs {
                            let el = node_label(e as u32 + 1);
                            let lbl = [("scheme", name), ("edge", el.as_str())];
                            reg.gauge_set("surveiledge_overload_pressure", &lbl, pressure);
                            reg.gauge_set(
                                "surveiledge_overload_ladder_level",
                                &lbl,
                                des.ladders[e].level() as u8 as f64,
                            );
                            reg.gauge_set("surveiledge_overload_queue_depth", &lbl, node_q as f64);
                            reg.gauge_set("surveiledge_overload_uplink_depth", &lbl, up_q as f64);
                        }
                    }
                }
                // Detect on every camera at this tick (the shared detect
                // stage, pipeline::detect_crops).
                for ci in 0..cameras.len() {
                    let frame = cameras[ci].frame_at(t);
                    let truth = cameras[ci].truth_at(t);
                    let Some((f_prev2, f_prev)) = prev_frames[ci].take() else {
                        prev_frames[ci] = Some((frame.image.clone(), frame.image));
                        continue;
                    };
                    let home = cam_edge[ci];
                    // Ladder level at this edge, and the scenario's burst
                    // multiplier: each detection is admitted `reps` times
                    // during a burst window (always 1 without overload).
                    let lvl = if des.ocfg.enabled {
                        des.ladders[(home - 1) as usize].level()
                    } else {
                        LoadLevel::Normal
                    };
                    let reps = if des.ocfg.enabled { des.ocfg.burst_factor(t) } else { 1 };
                    for det in
                        pipeline::detect_crops(&f_prev2, &f_prev, &frame.image, &truth, &detect_cfg)
                    {
                    for _rep in 0..reps {
                        // Ladder rung 1 — frame subsampling: thin the
                        // offered load before it becomes a task. The
                        // decision is a stateless hash of (seed, id), so
                        // same-seed reruns skip the same detections. A
                        // skipped detection consumes its task id but is
                        // never counted as a task, so it cannot read as
                        // "lost".
                        if lvl >= LoadLevel::Subsample
                            && unit_hash(cfg.seed, 0x5AB5, next_task_id) < des.ocfg.subsample_drop
                        {
                            h.span(name, t, next_task_id, Stage::Subsample, home, 0.0, "");
                            if let Some(reg) = &h.obs {
                                reg.inc(
                                    "surveiledge_overload_subsampled_total",
                                    &[("scheme", name)],
                                    1,
                                );
                            }
                            next_task_id += 1;
                            continue;
                        }
                        let (oracle_positive, synth_confidence) =
                            h.mode.judge(cfg.query, &det.crop, det.truth_cls, &mut rng)?;
                        // Per-query views of the one shared result. The
                        // scenario class reuses the draw above; other
                        // classes get a task+class-keyed derived stream,
                        // so admitting or retiring one query never
                        // shifts another query's confidences.
                        let (qviews, route_weight, class) = match &h.queries {
                            Some(qs) => {
                                let cam = CameraId(ci as u32);
                                let mut views = Vec::new();
                                for (qi, spec) in qs.active(cam, t) {
                                    let (oracle, conf) = if spec.object == cfg.query {
                                        (oracle_positive, synth_confidence.unwrap_or(0.5))
                                    } else {
                                        let mut qrng = Rng::new(
                                            cfg.seed
                                                ^ 0x9E3779B97F4A7C15u64
                                                    .wrapping_mul(next_task_id.wrapping_add(1))
                                                ^ ((spec.object.index() as u64) << 48),
                                        );
                                        h.mode.judge_shared(spec.object, det.truth_cls, &mut qrng)
                                    };
                                    views.push(TaskQueryView {
                                        query: qi,
                                        confidence: conf,
                                        oracle,
                                    });
                                }
                                (views, qs.route_weight(cam, t), qs.dominant_class(cam, t))
                            }
                            None => (Vec::new(), 1.0, DeadlineClass::Standard),
                        };
                        let task = SimTask {
                            id: next_task_id,
                            t_capture: t - cfg.interval, // crop comes from the middle frame
                            home_edge: home,
                            wire_bytes: (det.expanded.area() as u64) * 3 * HD_SCALE,
                            truth_positive: det.truth_cls.map(|c| c == cfg.query),
                            crop: match &h.mode {
                                #[cfg(feature = "pjrt")]
                                ComputeMode::Pjrt(_) => det.crop.data.clone(),
                                ComputeMode::Synthetic { .. } => Vec::new(),
                            },
                            oracle_positive,
                            synth_confidence,
                            attempt: 0,
                            doubtful: false,
                            t_enqueue: t,
                            qviews,
                            route_weight,
                            class,
                        };
                        next_task_id += 1;
                        result.tasks += 1;
                        // Detection span: frame-diff ran on the middle
                        // frame; the crop surfaces one interval later.
                        h.span(name, t, task.id, Stage::Detect, task.home_edge, t - task.t_capture, "");
                        // Ladder rung 3 — admission shedding: at the top
                        // rung, batch-class work is dropped outright (an
                        // explicit shed, not a loss); standard and
                        // interactive still ride the bounded queues.
                        if des.ocfg.enabled
                            && lvl >= LoadLevel::Shed
                            && task.class == DeadlineClass::Batch
                        {
                            shed_finish(h, name, &task, t, &mut des, &mut result, "ladder");
                            continue;
                        }
                        // Route (eq. 7 or the scheme's fixed policy). An
                        // open uplink breaker removes the cloud from
                        // candidacy before the allocator runs.
                        let open = breaker_open(h, name, &mut des, task.home_edge, t, task.id);
                        let dest = route_task(
                            h,
                            policy,
                            task.home_edge,
                            t,
                            &des,
                            &db,
                            task.route_weight,
                            open,
                        );
                        dispatch(h, policy, task, dest, t, &mut des, &db, &mut result)?;
                    }
                    }
                    prev_frames[ci] = Some((f_prev, frame.image));
                }
            }
            Event::NodeFinish { node } => {
                let n = node as usize;
                des.nodes[n].finish_ev = None;
                let mut task = des.nodes[n].queue.pop_front().expect("finish without task");
                des.nodes[n].busy = false;
                let service =
                    service_time(node, &des.nodes[n], &h.times) * des.fx.plan.slowdown(node, t);
                des.nodes[n].estimator.observe(service);
                // Queue wait = time between entering this node's FIFO and
                // service start (clamped: the slowdown factor can differ
                // between scheduling and completion).
                let qwait = (t - service - task.t_enqueue).max(0.0);
                h.span(name, t - service, task.id, Stage::Queue, node, qwait, "");
                let infer_stage = if node == 0 { Stage::CloudInfer } else { Stage::EdgeInfer };
                h.span(name, t, task.id, infer_stage, node, service, "");
                if node == 0 {
                    // Cloud verdict: the oracle's answer, by definition.
                    let latency = (t - task.t_capture) + cfg.rtt / 2.0;
                    finish(h, &mut result, name, &task, task.oracle_positive, latency, t, "cloud");
                } else {
                    // Edge classify -> the shared band-decision stage.
                    let conf = confidence_of(h, &task)?;
                    let e = (node - 1) as usize;
                    // Controller signal (eq. 8's l_d·t_d): the expected
                    // latency of the *re-classification path* a doubtful
                    // image would take — uplink backlog + cloud queue —
                    // plus the rtt. When uploads congest the uplink, the
                    // band narrows; with headroom it widens. Band width
                    // only changes the *upload* volume, so the eq. 8
                    // signal tracks the doubtful path. (Edge queueing is
                    // the allocator's job, eq. 7.)
                    // An open breaker on this task's uplink blocks the
                    // doubtful upload path exactly like a dead cloud —
                    // the stage layer degrades to an edge-local verdict.
                    let blocked = breaker_open(h, name, &mut des, task.home_edge, t, task.id);
                    let ctx = DesCtx {
                        signal: des.uplinks[e].queued_bytes as f64 / uplink_bps
                            + (des.nodes[0].queue.len() + des.nodes[0].busy as usize) as f64
                                * des.nodes[0].estimator.estimate()
                            + cfg.rtt,
                        // Graceful degradation only exists under a fault
                        // plan (fault-free runs never schedule
                        // heartbeats).
                        cloud_alive: (!faulty || node_alive(&db, 0, t)) && !blocked,
                        queries: h.queries.as_ref(),
                        level: if des.ocfg.enabled {
                            des.ladders[e].level()
                        } else {
                            LoadLevel::Normal
                        },
                    };
                    let outcome = pipeline::classify_stage(&ctx, policy, &mut controllers[e], conf);
                    band_width_acc += controllers[e].band_width();
                    band_width_n += 1;
                    h.span(name, t, task.id, Stage::ThresholdDecide, node, 0.0, outcome.band());
                    match outcome.action {
                        EdgeAction::Verdict { positive } => {
                            finish(
                                h,
                                &mut result,
                                name,
                                &task,
                                positive,
                                t - task.t_capture,
                                t,
                                "edge",
                            );
                        }
                        EdgeAction::Degrade { .. } => {
                            // The cloud's heartbeat is stale: answer with
                            // the edge confidence rather than queue into a
                            // dead path.
                            degrade_finish(h, policy, task, t, &mut des, &mut result)?;
                        }
                        EdgeAction::Upload => {
                            result.uploads += 1;
                            task.doubtful = true;
                            let e = (task.home_edge - 1) as usize;
                            push_uplink_bounded(h, name, &mut des, e, task, t, &mut result);
                        }
                    }
                }
                // Start the next queued task, if any.
                des.start_if_idle(n, t);
            }
            Event::NodeResume { node } => {
                let n = node as usize;
                des.nodes[n].busy = false;
                des.start_if_idle(n, t);
            }
            Event::UplinkFinish { edge } => {
                let e = edge as usize;
                let task = des.uplinks[e].queue.pop_front().expect("uplink finish without task");
                des.uplinks[e].queued_bytes =
                    des.uplinks[e].queued_bytes.saturating_sub(task.wire_bytes);
                des.uplinks[e].busy = false;
                des.kick_uplink(e, t);
                // Uplink span covers queue wait + the wire transfer.
                h.span(name, t, task.id, Stage::Uplink, edge + 1, t - task.t_enqueue, "");
                let failed = des.fx.plan.drops(task.id, task.attempt) || des.fx.plan.is_down(0, t);
                // Breaker feedback: every completed transfer is either an
                // ack (success) or an ack-timeout (failure). Consecutive
                // timeouts trip the circuit open.
                if des.ocfg.enabled {
                    let tr = if failed {
                        des.breakers[e].on_failure(t)
                    } else {
                        des.breakers[e].on_success(t)
                    };
                    breaker_transition(h, name, e, t, task.id, tr);
                }
                if failed {
                    // Lost in transit, or the cloud is down: no ack
                    // arrives before the timeout.
                    retry_or_degrade(h, policy, task, t, &mut des, &db, &mut result)?;
                } else {
                    // Deliver to the cloud queue after half an RTT (+ any
                    // injected one-way delay).
                    let arrival = t + cfg.rtt / 2.0 + des.fx.plan.delay_of(task.id);
                    enqueue_node_bounded(h, name, &mut des, 0, task, arrival, &mut result);
                }
            }
            Event::Heartbeat => {
                for n in 0..des.nodes.len() as u32 {
                    if !des.fx.plan.is_down(n, t) {
                        db.put(&ParamDb::key_hb(n), Value::F64(t));
                    }
                }
                if t + HB_INTERVAL <= drain_until {
                    des.schedule(t + HB_INTERVAL, Event::Heartbeat);
                }
            }
            Event::FaultCrash { node } => {
                // The in-flight task (if any) is lost mid-service: cancel
                // its completion. The task itself stays at the queue
                // front for the failover sweep / restart.
                let n = node as usize;
                if let Some(ev_id) = des.nodes[n].finish_ev.take() {
                    des.events.remove(&ev_id);
                    des.nodes[n].busy = false;
                }
            }
            Event::FaultRecover { node } => {
                des.start_if_idle(node as usize, t);
            }
            Event::Failover { node, crash_from } => {
                // Stale-heartbeat detection point: if the node is still
                // down, re-queue its stranded tasks through the allocator
                // (which now excludes it).
                if des.fx.plan.is_down(node, t) {
                    let stranded: Vec<SimTask> = des.nodes[node as usize].queue.drain(..).collect();
                    if !stranded.is_empty() && des.fstats.time_to_reroute == 0.0 {
                        des.fstats.time_to_reroute = t - crash_from;
                    }
                    for task in stranded {
                        des.fstats.rerouted += 1;
                        h.span(name, t, task.id, Stage::Reroute, node, 0.0, "");
                        let open = breaker_open(h, name, &mut des, task.home_edge, t, task.id);
                        let dest = route_task(
                            h,
                            policy,
                            task.home_edge,
                            t,
                            &des,
                            &db,
                            task.route_weight,
                            open,
                        );
                        dispatch(h, policy, task, dest, t, &mut des, &db, &mut result)?;
                    }
                }
            }
            Event::Redispatch { task } => {
                // The retry this event carried is no longer in flight —
                // release its slot in the per-edge budget.
                if des.ocfg.enabled && des.ocfg.retry_budget > 0 {
                    let e = (task.home_edge - 1) as usize;
                    des.retry_inflight[e] = des.retry_inflight[e].saturating_sub(1);
                }
                if task.doubtful {
                    if !node_alive(&db, 0, t)
                        || breaker_open(h, name, &mut des, task.home_edge, t, task.id)
                    {
                        // Still no cloud (dead, or its uplink is shunned):
                        // answer locally instead of re-uploading into a
                        // dead path.
                        degrade_finish(h, policy, task, t, &mut des, &mut result)?;
                    } else {
                        let e = (task.home_edge - 1) as usize;
                        push_uplink_bounded(h, name, &mut des, e, task, t, &mut result);
                    }
                } else {
                    let open = breaker_open(h, name, &mut des, task.home_edge, t, task.id);
                    let dest = route_task(
                        h,
                        policy,
                        task.home_edge,
                        t,
                        &des,
                        &db,
                        task.route_weight,
                        open,
                    );
                    dispatch(h, policy, task, dest, t, &mut des, &db, &mut result)?;
                }
            }
        }
    }

    let f2 = result.vs_oracle.f2();
    result.row.accuracy = f2;
    result.row.avg_latency = result.latency.mean();
    result.row.bandwidth_mb = des.cloud_bytes as f64 / (1024.0 * 1024.0);
    result.mean_band_width =
        if band_width_n > 0 { band_width_acc / band_width_n as f64 } else { 0.0 };
    result.faults = des.fstats;
    // Zero-lost invariant: every admitted task is completed, degraded, or
    // *explicitly* shed. Only the unaccounted remainder is "lost".
    result.faults.lost = result
        .tasks
        .saturating_sub(result.latency.len() as u64)
        .saturating_sub(result.faults.shed);
    if let Some(qs) = &h.queries {
        result.per_query = qs.per_query_reports(&result.query_verdicts);
    }
    if let Some(reg) = &h.obs {
        let sl = [("scheme", name)];
        reg.inc("surveiledge_harness_tasks_total", &sl, result.tasks);
        reg.inc("surveiledge_harness_uploads_total", &sl, result.uploads);
        reg.inc("surveiledge_harness_uplink_bytes_total", &sl, des.cloud_bytes);
        reg.gauge_set("surveiledge_harness_accuracy_f2", &sl, result.row.accuracy);
        reg.gauge_set("surveiledge_harness_avg_latency_seconds", &sl, result.row.avg_latency);
        reg.gauge_set("surveiledge_harness_bandwidth_mb", &sl, result.row.bandwidth_mb);
        reg.gauge_set("surveiledge_harness_mean_band_width", &sl, result.mean_band_width);
        reg.inc("surveiledge_faults_retried_total", &sl, result.faults.retried);
        reg.inc("surveiledge_faults_rerouted_total", &sl, result.faults.rerouted);
        reg.inc("surveiledge_faults_degraded_total", &sl, result.faults.degraded);
        reg.inc("surveiledge_faults_lost_total", &sl, result.faults.lost);
        reg.gauge_set("surveiledge_faults_time_to_reroute_seconds", &sl, result.faults.time_to_reroute);
        // Overload runs only — an [overload]-free export stays
        // byte-identical to the pre-overload key set.
        if des.ocfg.enabled {
            reg.inc("surveiledge_faults_shed_total", &sl, result.faults.shed);
            reg.gauge_set("surveiledge_overload_max_queue_depth", &sl, des.max_depth as f64);
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;
    use std::cmp::Ordering;

    #[test]
    fn heap_key_orders_by_time_then_seq() {
        assert!(HeapKey(1.0, 9) < HeapKey(2.0, 0), "earlier time wins regardless of seq");
        assert!(HeapKey(1.0, 0) < HeapKey(1.0, 1), "same time: scheduling order breaks the tie");
        assert_eq!(HeapKey(1.5, 3).cmp(&HeapKey(1.5, 3)), Ordering::Equal);
        assert_eq!(HeapKey(1.5, 3), HeapKey(1.5, 3));
        assert_ne!(HeapKey(1.5, 3), HeapKey(1.5, 4), "Eq must agree with the seq tie-break");
        assert_ne!(HeapKey(1.5, 3), HeapKey(2.5, 3));
        // -0.0 == 0.0 in IEEE 754; the seq field still separates the keys.
        assert_eq!(HeapKey(-0.0, 1).cmp(&HeapKey(0.0, 1)), Ordering::Equal);
        assert!(HeapKey(-0.0, 0) < HeapKey(0.0, 1));
    }

    #[test]
    fn prop_heap_key_is_a_total_order_over_finite_times() {
        check("heap_key_total_order", |rng, _case| {
            // Draw times from a small pool so same-time pairs (the
            // tie-break path) actually occur.
            let pool: Vec<f64> = (0..4).map(|_| rng.range_f64(0.0, 1e6)).collect();
            let key = |rng: &mut crate::testkit::Rng| {
                HeapKey(pool[rng.range_usize(0, pool.len())], rng.next_u64() % 8)
            };
            let (a, b, c) = (key(rng), key(rng), key(rng));
            // Antisymmetry and Eq-consistency.
            assert_eq!(a.cmp(&b), b.cmp(&a).reverse());
            assert_eq!(a.cmp(&b) == Ordering::Equal, a == b);
            assert_eq!(a.cmp(&a), Ordering::Equal);
            // Transitivity.
            if a.cmp(&b) != Ordering::Greater && b.cmp(&c) != Ordering::Greater {
                assert_ne!(a.cmp(&c), Ordering::Greater, "transitivity violated");
            }
            // The tie-break is exactly the seq order.
            if a.0 == b.0 {
                assert_eq!(a.cmp(&b), a.1.cmp(&b.1));
            }
        });
    }

    #[test]
    fn prop_heap_pops_in_nondecreasing_time_order() {
        check("heap_key_pop_order", |rng, _case| {
            let mut des = Des {
                nodes: Vec::new(),
                uplinks: Vec::new(),
                heap: BinaryHeap::new(),
                events: HashMap::new(),
                seq: 0,
                cloud_bytes: 0,
                fstats: FaultStats::default(),
                times: ServiceTimes::default(),
                uplink_bps: 1.0,
                fx: FaultCtx { plan: FaultPlan::none(), outage: None },
                ocfg: OverloadConfig::default(),
                breakers: Vec::new(),
                ladders: Vec::new(),
                retry_inflight: Vec::new(),
                max_depth: 0,
            };
            for _ in 0..32 {
                // Repeated times exercise the seq tie-break.
                let t = (rng.range_f64(0.0, 8.0) * 4.0).floor() / 4.0;
                des.schedule(t, Event::Heartbeat);
            }
            let mut last = f64::NEG_INFINITY;
            let mut last_seq = 0u64;
            while let Some(Reverse((HeapKey(t, id), _))) = des.heap.pop() {
                assert!(t >= last, "heap popped {t} after {last}");
                if t == last {
                    assert!(id > last_seq, "same-time events must pop in scheduling order");
                }
                last = t;
                last_seq = id;
            }
        });
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn scheduling_a_nan_time_is_rejected() {
        let mut des = Des {
            nodes: Vec::new(),
            uplinks: Vec::new(),
            heap: BinaryHeap::new(),
            events: HashMap::new(),
            seq: 0,
            cloud_bytes: 0,
            fstats: FaultStats::default(),
            times: ServiceTimes::default(),
            uplink_bps: 1.0,
            fx: FaultCtx { plan: FaultPlan::none(), outage: None },
            ocfg: OverloadConfig::default(),
            breakers: Vec::new(),
            ladders: Vec::new(),
            retry_inflight: Vec::new(),
            max_depth: 0,
        };
        des.schedule(f64::NAN, Event::Heartbeat);
    }
}
