//! Multi-query frontend: specs, admission control, work sharing, and
//! streaming results.
//!
//! SurveilEdge's point is *querying* surveillance video, yet a bare
//! harness run answers exactly one implicit query. This module makes
//! queries first-class:
//!
//! * [`QuerySpec`] — target class, camera set, `[β, α]` confidence band,
//!   deadline class, and active time window, parsed from `[[query]]`
//!   TOML blocks ([`QueryFile`]).
//! * [`QueryRegistry`] — admits/retires queries at runtime. Admission is
//!   load-aware: the projected edge + uplink utilization of the admitted
//!   set (fed by the `estimator`'s latency predictions) must stay under a
//!   configurable headroom.
//! * **Work sharing** — N queries over the same camera run detection and
//!   edge classification *once* per frame; only the per-query threshold
//!   decision fans out from the shared result ([`TaskQueryView`],
//!   [`QuerySpec::decide`]).
//! * **Streaming results** — every per-query verdict is published on the
//!   bus topic `query/<id>/results` ([`QuerySet::publish_result`]) and
//!   exported as deterministic JSONL ([`write_results`]): same seed ⇒
//!   byte-identical files.
//!
//! Both substrates use the same types: the DES engine carries
//! [`TaskQueryView`]s on its simulated tasks and fans out at verdict
//! time; the live `nodes::EdgeWorker` holds a [`QuerySet`] and publishes
//! from its classify path.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::bus::{Broker, Message, QoS};
use crate::config::toml::TomlDoc;
use crate::config::Config;
use crate::estimator::LatencyEstimator;
use crate::obs::{Registry, Report, SpanEvent, Stage};
use crate::types::{CameraId, ClassId};

/// How urgently a query needs answers — its weight in eq. 7 routing.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DeadlineClass {
    /// A user is watching; outbids everything else for fast paths.
    Interactive,
    /// The default: the paper's real-time query.
    Standard,
    /// Forensic/batch scan; happy to wait out congestion.
    Batch,
}

impl DeadlineClass {
    pub fn as_str(self) -> &'static str {
        match self {
            DeadlineClass::Interactive => "interactive",
            DeadlineClass::Standard => "standard",
            DeadlineClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<DeadlineClass> {
        match s {
            "interactive" => Some(DeadlineClass::Interactive),
            "standard" => Some(DeadlineClass::Standard),
            "batch" => Some(DeadlineClass::Batch),
            _ => None,
        }
    }

    /// Multiplier on the eq. 7 congestion penalty: > 1 makes congested
    /// paths look worse (the query flees to fast nodes), < 1 makes them
    /// tolerable. `Standard` is exactly the no-query behavior.
    pub fn weight(self) -> f64 {
        match self {
            DeadlineClass::Interactive => 2.0,
            DeadlineClass::Standard => 1.0,
            DeadlineClass::Batch => 0.5,
        }
    }
}

/// One continuous query: "find `object` on `cameras` between `from` and
/// `until`, deciding locally outside the `[beta, alpha]` doubt band".
#[derive(Clone, Debug, PartialEq)]
pub struct QuerySpec {
    /// Unique id — also the bus topic segment (`query/<id>/results`), so
    /// it is restricted to `[A-Za-z0-9_-]`.
    pub id: String,
    pub object: ClassId,
    /// Cameras this query watches; empty = every camera.
    pub cameras: Vec<CameraId>,
    /// Upper band edge: confidence ≥ α answers positive at the edge.
    pub alpha: f64,
    /// Lower band edge: confidence ≤ β answers negative at the edge.
    pub beta: f64,
    pub deadline: DeadlineClass,
    /// Active window start (scenario seconds, inclusive).
    pub from: f64,
    /// Active window end (exclusive; `f64::INFINITY` = never retires).
    pub until: f64,
}

impl QuerySpec {
    /// A standard always-on query over every camera with the paper's
    /// initial band (α₀ = 0.8, β₀ = 0.1).
    pub fn new(id: &str, object: ClassId) -> QuerySpec {
        QuerySpec {
            id: id.to_string(),
            object,
            cameras: Vec::new(),
            alpha: 0.8,
            beta: 0.1,
            deadline: DeadlineClass::Standard,
            from: 0.0,
            until: f64::INFINITY,
        }
    }

    pub fn covers(&self, camera: CameraId) -> bool {
        self.cameras.is_empty() || self.cameras.contains(&camera)
    }

    pub fn active_at(&self, t: f64) -> bool {
        t >= self.from && t < self.until
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            !self.id.is_empty()
                && self.id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "query id {:?} must be non-empty [A-Za-z0-9_-] (it names a bus topic)",
            self.id
        );
        anyhow::ensure!(
            (0.5..=1.0).contains(&self.alpha),
            "query {:?}: alpha {} outside [0.5, 1]",
            self.id,
            self.alpha
        );
        anyhow::ensure!(
            self.beta >= 0.0 && self.beta < self.alpha,
            "query {:?}: beta {} outside [0, alpha)",
            self.id,
            self.beta
        );
        anyhow::ensure!(
            self.from >= 0.0 && self.until > self.from,
            "query {:?}: window [{}, {}) is empty or negative",
            self.id,
            self.from,
            self.until
        );
        Ok(())
    }

    /// Per-query threshold decision on the *shared* edge confidence.
    /// Returns `(positive, site)` where site ∈ {"edge", "cloud", "local"}:
    ///
    /// * confidence ≥ α → positive at the edge;
    /// * confidence ≤ β → negative at the edge;
    /// * doubtful: if the shared task was resolved by the cloud
    ///   (`shared_cloud`), adopt the oracle answer ("cloud"); otherwise
    ///   fall back to a local 0.5 split ("local") — the shared pipeline
    ///   did not pay an upload for this frame, so neither may the query.
    pub fn decide(&self, confidence: f32, oracle: bool, shared_cloud: bool) -> (bool, &'static str) {
        if confidence as f64 >= self.alpha {
            (true, "edge")
        } else if confidence as f64 <= self.beta {
            (false, "edge")
        } else if shared_cloud {
            (oracle, "cloud")
        } else {
            (confidence >= 0.5, "local")
        }
    }
}

/// A query's view of one shared task: which registered query (index into
/// the sorted [`QuerySet`]) and the shared per-class inference result it
/// will threshold. Computed once at capture; the decision fans out at
/// verdict time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskQueryView {
    /// Index into [`QuerySet::specs`] (sorted by id).
    pub query: usize,
    /// Shared edge confidence for this query's object class.
    pub confidence: f32,
    /// What the cloud model would answer for this query's object class.
    pub oracle: bool,
}

/// One entry of a query's incremental result stream.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryVerdict {
    pub query: String,
    pub task: u64,
    /// Simulated/wall time the verdict was produced.
    pub t: f64,
    pub positive: bool,
    /// The shared edge confidence this decision thresholded.
    pub confidence: f32,
    /// Where the decision came from: "edge", "cloud", or "local" — or
    /// "shed" when overload control explicitly dropped the task (the row
    /// is accounting, not an answer: `positive` is always false).
    pub site: &'static str,
    /// End-to-end latency of the shared task (seconds).
    pub latency: f64,
}

fn site_code(site: &str) -> u8 {
    match site {
        "edge" => 0,
        "cloud" => 1,
        "shed" => 3,
        _ => 2,
    }
}

fn site_from_code(code: u8) -> &'static str {
    match code {
        0 => "edge",
        1 => "cloud",
        3 => "shed",
        _ => "local",
    }
}

/// Wire encoding for `query/<id>/results` payloads (little-endian, fixed
/// layout — deterministic byte-for-byte).
pub fn encode_query_verdict(v: &QueryVerdict) -> Vec<u8> {
    let id = v.query.as_bytes();
    let mut out = Vec::with_capacity(2 + id.len() + 8 + 8 + 8 + 4 + 2);
    out.extend_from_slice(&(id.len() as u16).to_le_bytes());
    out.extend_from_slice(id);
    out.extend_from_slice(&v.task.to_le_bytes());
    out.extend_from_slice(&v.t.to_le_bytes());
    out.extend_from_slice(&v.latency.to_le_bytes());
    out.extend_from_slice(&v.confidence.to_le_bytes());
    out.push(u8::from(v.positive));
    out.push(site_code(v.site));
    out
}

pub fn decode_query_verdict(bytes: &[u8]) -> crate::Result<QueryVerdict> {
    let take = |b: &[u8], at: usize, n: usize| -> crate::Result<Vec<u8>> {
        anyhow::ensure!(b.len() >= at + n, "query verdict frame truncated at byte {at}");
        Ok(b[at..at + n].to_vec())
    };
    let id_len = u16::from_le_bytes(take(bytes, 0, 2)?.try_into().unwrap()) as usize;
    let id = String::from_utf8(take(bytes, 2, id_len)?)
        .map_err(|_| anyhow::anyhow!("query verdict id is not UTF-8"))?;
    let mut at = 2 + id_len;
    let task = u64::from_le_bytes(take(bytes, at, 8)?.try_into().unwrap());
    at += 8;
    let t = f64::from_le_bytes(take(bytes, at, 8)?.try_into().unwrap());
    at += 8;
    let latency = f64::from_le_bytes(take(bytes, at, 8)?.try_into().unwrap());
    at += 8;
    let confidence = f32::from_le_bytes(take(bytes, at, 4)?.try_into().unwrap());
    at += 4;
    let flags = take(bytes, at, 2)?;
    anyhow::ensure!(bytes.len() == at + 2, "query verdict frame has trailing bytes");
    Ok(QueryVerdict {
        query: id,
        task,
        t,
        positive: flags[0] != 0,
        confidence,
        site: site_from_code(flags[1]),
        latency,
    })
}

/// The admitted queries a pipeline run executes against, sorted by id so
/// every admission order yields the same set (and the same indices for
/// [`TaskQueryView::query`]).
#[derive(Clone, Default)]
pub struct QuerySet {
    specs: Vec<QuerySpec>,
    broker: Option<Broker>,
}

impl QuerySet {
    pub fn new(mut specs: Vec<QuerySpec>) -> crate::Result<QuerySet> {
        for s in &specs {
            s.validate()?;
        }
        specs.sort_by(|a, b| a.id.cmp(&b.id));
        for pair in specs.windows(2) {
            anyhow::ensure!(pair[0].id != pair[1].id, "duplicate query id {:?}", pair[0].id);
        }
        Ok(QuerySet { specs, broker: None })
    }

    /// Attach a broker: every verdict fans out to `query/<id>/results`.
    pub fn with_broker(mut self, broker: Broker) -> QuerySet {
        self.broker = Some(broker);
        self
    }

    pub fn specs(&self) -> &[QuerySpec] {
        &self.specs
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Queries covering `camera` and active at `t`, with their indices.
    pub fn active(&self, camera: CameraId, t: f64) -> impl Iterator<Item = (usize, &QuerySpec)> {
        self.specs
            .iter()
            .enumerate()
            .filter(move |(_, s)| s.covers(camera) && s.active_at(t))
    }

    /// eq. 7 routing weight for a task from `camera` at `t`: the most
    /// demanding active query's deadline weight (1.0 when no query is
    /// active — identical to a query-less run).
    pub fn route_weight(&self, camera: CameraId, t: f64) -> f64 {
        self.active(camera, t)
            .map(|(_, s)| s.deadline.weight())
            .fold(None, |acc: Option<f64>, w| Some(acc.map_or(w, |a| a.max(w))))
            .unwrap_or(1.0)
    }

    /// The most demanding active deadline class for a task from `camera`
    /// at `t` — what overload control's shed policy protects. `Standard`
    /// when no query is active (matching [`QuerySet::route_weight`]'s
    /// 1.0 default).
    pub fn dominant_class(&self, camera: CameraId, t: f64) -> DeadlineClass {
        self.active(camera, t)
            .map(|(_, s)| s.deadline)
            .fold(None, |acc: Option<DeadlineClass>, c| {
                Some(acc.map_or(c, |a| if c.weight() > a.weight() { c } else { a }))
            })
            .unwrap_or(DeadlineClass::Standard)
    }

    /// Publish one verdict on `query/<id>/results` (QoS 0 — results are
    /// a stream; a full subscriber queue drops, it never stalls the
    /// pipeline).
    pub fn publish_result(&self, v: &QueryVerdict) {
        if let Some(b) = &self.broker {
            let topic = format!("query/{}/results", v.query);
            b.publish(Message::new(topic, encode_query_verdict(v)), QoS::AtMostOnce);
        }
    }

    /// One stable [`Report`] per query (in id order) summarizing its
    /// verdict stream.
    pub fn per_query_reports(&self, verdicts: &[QueryVerdict]) -> Vec<Report> {
        self.specs
            .iter()
            .map(|spec| {
                let mut r = Report::new("query_run", &spec.id);
                // Shed rows are accounting, not answers: they carry their
                // own counter and stay out of every answer statistic, so
                // a run that sheds nothing reports byte-identically to a
                // pre-overload build.
                let shed = verdicts
                    .iter()
                    .filter(|v| v.query == spec.id && v.site == "shed")
                    .count();
                let mine: Vec<&QueryVerdict> = verdicts
                    .iter()
                    .filter(|v| v.query == spec.id && v.site != "shed")
                    .collect();
                let positives = mine.iter().filter(|v| v.positive).count();
                let cloud = mine.iter().filter(|v| v.site == "cloud").count();
                let local = mine.iter().filter(|v| v.site == "local").count();
                let lat_sum: f64 = mine.iter().map(|v| v.latency).sum();
                r.push("verdicts", mine.len() as f64);
                r.push("positives", positives as f64);
                r.push("negatives", (mine.len() - positives) as f64);
                r.push("doubtful_cloud", cloud as f64);
                r.push("doubtful_local", local as f64);
                r.push(
                    "mean_latency_s",
                    if mine.is_empty() { 0.0 } else { lat_sum / mine.len() as f64 },
                );
                if shed > 0 {
                    r.push("shed", shed as f64);
                }
                r
            })
            .collect()
    }
}

/// Deterministic JSONL rendering of one query's verdict stream (fixed
/// key order; non-finite numbers render as 0).
pub fn verdicts_jsonl(verdicts: &[QueryVerdict], id: &str) -> String {
    fn jf64(v: f64) -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "0".to_string()
        }
    }
    fn jf32(v: f32) -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "0".to_string()
        }
    }
    let mut out = String::new();
    for v in verdicts.iter().filter(|v| v.query == id) {
        out.push_str(&format!(
            "{{\"query\":\"{}\",\"task\":{},\"t\":{},\"positive\":{},\"confidence\":{},\"site\":\"{}\",\"latency\":{}}}\n",
            v.query,
            v.task,
            jf64(v.t),
            v.positive,
            jf32(v.confidence),
            v.site,
            jf64(v.latency)
        ));
    }
    out
}

/// Write one `query_<id>.jsonl` per spec into `dir` (created if missing;
/// empty streams still produce an empty file so reruns are comparable
/// file-by-file). Returns the written paths in id order.
pub fn write_results(
    dir: &Path,
    verdicts: &[QueryVerdict],
    specs: &[QuerySpec],
) -> crate::Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(specs.len());
    for spec in specs {
        let path = dir.join(format!("query_{}.jsonl", spec.id));
        std::fs::write(&path, verdicts_jsonl(verdicts, &spec.id))?;
        paths.push(path);
    }
    Ok(paths)
}

/// Load model behind admission control: projected utilization of the
/// edge fleet and the uplink as a function of how many cameras the
/// admitted queries collectively watch. Latencies come from the
/// `estimator` so the projection tracks the observed system.
#[derive(Clone, Debug)]
pub struct AdmissionModel {
    /// Mean crops (classification tasks) per camera per sampling tick.
    pub crops_per_tick: f64,
    /// Query sampling interval `s` (seconds).
    pub interval: f64,
    /// Cameras in the deployment (an empty-`cameras` query watches all).
    pub total_cameras: u32,
    /// Σ edge speed factors — task-per-`edge_infer`-second capacity unit.
    pub edge_capacity: f64,
    /// Fraction of crops that land in the doubt band and ship uplink.
    pub upload_fraction: f64,
    edge_est: LatencyEstimator,
    uplink_est: LatencyEstimator,
}

impl AdmissionModel {
    /// `edge_infer` seeds the edge latency estimate (use
    /// `ServiceTimes::default().edge_infer`); the uplink estimate seeds
    /// from one crop's wire time on the configured uplink.
    pub fn from_config(cfg: &Config, edge_infer: f64, crop_wire_bytes: u64) -> AdmissionModel {
        AdmissionModel {
            crops_per_tick: 1.5,
            interval: cfg.interval,
            total_cameras: cfg.total_cameras(),
            edge_capacity: cfg.edges.iter().map(|e| e.speed).sum::<f64>().max(1e-9),
            upload_fraction: 0.35,
            edge_est: LatencyEstimator::new(edge_infer),
            uplink_est: LatencyEstimator::new(
                crop_wire_bytes as f64 / (cfg.uplink_mbps * 125_000.0),
            ),
        }
    }

    /// Feed an observed edge inference latency (tightens the projection).
    pub fn observe_edge(&mut self, t: f64) {
        self.edge_est.observe(t);
    }

    /// Feed an observed uplink transfer latency.
    pub fn observe_uplink(&mut self, t: f64) {
        self.uplink_est.observe(t);
    }

    /// Projected utilization when the admitted queries watch `cameras`
    /// distinct cameras: the max of edge-compute and uplink load, each a
    /// dimensionless busy fraction (1.0 = saturated).
    pub fn utilization(&self, cameras: u32) -> f64 {
        let rate = cameras as f64 * self.crops_per_tick / self.interval;
        let edge = rate * self.edge_est.estimate() / self.edge_capacity;
        let uplink = rate * self.upload_fraction * self.uplink_est.estimate();
        edge.max(uplink)
    }
}

struct RegInner {
    specs: Vec<QuerySpec>,
    model: AdmissionModel,
    headroom: f64,
    broker: Option<Broker>,
    obs: Option<Registry>,
}

/// Runtime query lifecycle: admit (with load-aware rejection) and
/// retire. Clones share state, so the registry can be polled from the
/// harness while a control plane admits/retires concurrently.
#[derive(Clone)]
pub struct QueryRegistry {
    inner: Arc<Mutex<RegInner>>,
}

impl QueryRegistry {
    pub fn new(model: AdmissionModel, headroom: f64) -> QueryRegistry {
        QueryRegistry {
            inner: Arc::new(Mutex::new(RegInner {
                specs: Vec::new(),
                model,
                headroom,
                broker: None,
                obs: None,
            })),
        }
    }

    /// Lifecycle events (`query/<id>/admitted|retired`) go on this bus.
    pub fn attach_broker(&self, broker: Broker) {
        self.inner.lock().unwrap().broker = Some(broker);
    }

    /// `query_admit`/`query_retire` spans + counters go here.
    pub fn attach_registry(&self, reg: Registry) {
        self.inner.lock().unwrap().obs = Some(reg);
    }

    /// Distinct cameras the given specs collectively watch (a spec with
    /// an empty camera set watches all `total_cameras`).
    fn union_cameras(specs: &[QuerySpec], total: u32) -> u32 {
        if specs.iter().any(|s| s.cameras.is_empty()) {
            return total;
        }
        let distinct: BTreeSet<CameraId> =
            specs.iter().flat_map(|s| s.cameras.iter().copied()).collect();
        (distinct.len() as u32).min(total)
    }

    /// Admit `spec` at time `now`. Rejects invalid specs, duplicate ids,
    /// and any admission that would push the projected load over the
    /// headroom — the error names the query and both load numbers.
    pub fn admit(&self, spec: QuerySpec, now: f64) -> crate::Result<()> {
        spec.validate()?;
        let mut inner = self.inner.lock().unwrap();
        anyhow::ensure!(
            !inner.specs.iter().any(|s| s.id == spec.id),
            "query {:?} is already admitted",
            spec.id
        );
        let mut proposed: Vec<QuerySpec> = inner.specs.clone();
        proposed.push(spec.clone());
        let cams = Self::union_cameras(&proposed, inner.model.total_cameras);
        let load = inner.model.utilization(cams);
        anyhow::ensure!(
            load <= inner.headroom,
            "admission rejected for query {:?}: projected load {:.3} exceeds headroom {:.3}",
            spec.id,
            load,
            inner.headroom
        );
        let at = inner.specs.partition_point(|s| s.id < spec.id);
        inner.specs.insert(at, spec.clone());
        if let Some(obs) = &inner.obs {
            obs.span(SpanEvent {
                t: now,
                task: 0,
                stage: Stage::QueryAdmit,
                node: 0,
                dur: 0.0,
                scheme: "registry".to_string(),
                detail: spec.id.clone(),
            });
            obs.inc("surveiledge_query_admitted_total", &[("query", &spec.id)], 1);
        }
        if let Some(b) = &inner.broker {
            b.publish(
                Message::new(format!("query/{}/admitted", spec.id), Vec::new()),
                QoS::AtMostOnce,
            );
        }
        Ok(())
    }

    /// Retire query `id` at time `now`. Unknown ids are an error.
    pub fn retire(&self, id: &str, now: f64) -> crate::Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let at = inner
            .specs
            .iter()
            .position(|s| s.id == id)
            .ok_or_else(|| anyhow::anyhow!("cannot retire unknown query {id:?}"))?;
        inner.specs.remove(at);
        if let Some(obs) = &inner.obs {
            obs.span(SpanEvent {
                t: now,
                task: 0,
                stage: Stage::QueryRetire,
                node: 0,
                dur: 0.0,
                scheme: "registry".to_string(),
                detail: id.to_string(),
            });
            obs.inc("surveiledge_query_retired_total", &[("query", id)], 1);
        }
        if let Some(b) = &inner.broker {
            b.publish(Message::new(format!("query/{id}/retired"), Vec::new()), QoS::AtMostOnce);
        }
        Ok(())
    }

    /// Projected utilization of the currently admitted set.
    pub fn projected_load(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        let cams = Self::union_cameras(&inner.specs, inner.model.total_cameras);
        inner.model.utilization(cams)
    }

    /// Feed an observed edge inference latency into the admission model.
    pub fn observe_edge(&self, t: f64) {
        self.inner.lock().unwrap().model.observe_edge(t);
    }

    /// Feed an observed uplink transfer latency into the admission model.
    pub fn observe_uplink(&self, t: f64) {
        self.inner.lock().unwrap().model.observe_uplink(t);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().specs.is_empty()
    }

    /// An immutable [`QuerySet`] of the currently admitted queries (the
    /// pipeline runs against snapshots, never the live registry).
    pub fn snapshot(&self) -> QuerySet {
        let inner = self.inner.lock().unwrap();
        QuerySet { specs: inner.specs.clone(), broker: inner.broker.clone() }
    }
}

/// Allowed keys of a `[[query]]` block — anything else is a named-key
/// error (satellite: no silent ignoring).
pub const QUERY_BLOCK_KEYS: [&str; 8] =
    ["id", "object", "cameras", "alpha", "beta", "deadline", "from", "until"];

/// A parsed `--spec` file: base scenario [`Config`] + `[[query]]` blocks
/// + `[admission]` headroom.
#[derive(Clone)]
pub struct QueryFile {
    pub cfg: Config,
    pub queries: Vec<QuerySpec>,
    /// Admission headroom (max projected utilization; default 0.8).
    pub headroom: f64,
}

impl QueryFile {
    pub fn parse(text: &str) -> crate::Result<QueryFile> {
        let cfg = Config::from_toml(text)?;
        let doc = TomlDoc::parse(text)?;
        let mut queries = Vec::new();
        for (i, block) in doc.blocks("query").enumerate() {
            let nth = i + 1;
            let id = block
                .get_str("id")
                .ok_or_else(|| anyhow::anyhow!("[[query]] block {nth}: missing id"))?;
            let ctx = format!("[[query]] block {nth} ({id:?})");
            block.ensure_keys(&ctx, &QUERY_BLOCK_KEYS)?;
            let object_name = block
                .get_str("object")
                .ok_or_else(|| anyhow::anyhow!("{ctx}: missing object"))?;
            let object = ClassId::from_name(&object_name)
                .ok_or_else(|| anyhow::anyhow!("{ctx}: unknown object {object_name:?}"))?;
            let mut spec = QuerySpec::new(&id, object);
            if let Some(cams) = block.get_i64_array("cameras") {
                for c in &cams {
                    anyhow::ensure!(*c >= 0, "{ctx}: negative camera id {c}");
                }
                spec.cameras = cams.iter().map(|&c| CameraId(c as u32)).collect();
            } else if block.get("cameras").is_some() {
                anyhow::bail!("{ctx}: cameras must be an integer array");
            }
            if let Some(v) = block.get_f64("alpha") {
                spec.alpha = v;
            }
            if let Some(v) = block.get_f64("beta") {
                spec.beta = v;
            }
            if let Some(d) = block.get_str("deadline") {
                spec.deadline = DeadlineClass::parse(&d)
                    .ok_or_else(|| anyhow::anyhow!("{ctx}: unknown deadline class {d:?}"))?;
            }
            if let Some(v) = block.get_f64("from") {
                spec.from = v;
            }
            if let Some(v) = block.get_f64("until") {
                spec.until = v;
            }
            spec.validate().map_err(|e| anyhow::anyhow!("{ctx}: {e}"))?;
            anyhow::ensure!(
                !queries.iter().any(|q: &QuerySpec| q.id == spec.id),
                "{ctx}: duplicate query id {:?}",
                spec.id
            );
            queries.push(spec);
        }
        let headroom = doc.get_f64("admission", "headroom").unwrap_or(0.8);
        anyhow::ensure!(headroom > 0.0, "admission.headroom must be positive");
        Ok(QueryFile { cfg, queries, headroom })
    }

    pub fn from_file(path: &Path) -> crate::Result<QueryFile> {
        let text = std::fs::read_to_string(path)?;
        QueryFile::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    fn spec(id: &str, object: ClassId, cams: &[u32]) -> QuerySpec {
        let mut s = QuerySpec::new(id, object);
        s.cameras = cams.iter().map(|&c| CameraId(c)).collect();
        s
    }

    fn model_for(cfg: &Config) -> AdmissionModel {
        AdmissionModel::from_config(cfg, 0.28, 24 * 24 * 3 * 225)
    }

    #[test]
    fn spec_validation() {
        assert!(QuerySpec::new("ok-id_1", ClassId::Moped).validate().is_ok());
        assert!(QuerySpec::new("", ClassId::Moped).validate().is_err());
        assert!(QuerySpec::new("bad/slash", ClassId::Moped).validate().is_err());
        let mut s = QuerySpec::new("q", ClassId::Moped);
        s.alpha = 0.4; // below the 0.5 split
        assert!(s.validate().is_err());
        let mut s = QuerySpec::new("q", ClassId::Moped);
        s.beta = 0.9; // >= alpha
        assert!(s.validate().is_err());
        let mut s = QuerySpec::new("q", ClassId::Moped);
        s.from = 10.0;
        s.until = 10.0; // empty window
        assert!(s.validate().is_err());
    }

    #[test]
    fn decide_bands_and_doubt_resolution() {
        let s = QuerySpec::new("q", ClassId::Moped);
        assert_eq!(s.decide(0.9, false, false), (true, "edge"));
        assert_eq!(s.decide(0.05, true, true), (false, "edge"));
        // Doubtful + shared task went to the cloud: adopt the oracle.
        assert_eq!(s.decide(0.5, true, true), (true, "cloud"));
        assert_eq!(s.decide(0.5, false, true), (false, "cloud"));
        // Doubtful + no shared upload: local 0.5 split.
        assert_eq!(s.decide(0.6, true, false), (true, "local"));
        assert_eq!(s.decide(0.4, true, false), (false, "local"));
    }

    #[test]
    fn deadline_weights_and_parse() {
        for d in [DeadlineClass::Interactive, DeadlineClass::Standard, DeadlineClass::Batch] {
            assert_eq!(DeadlineClass::parse(d.as_str()), Some(d));
        }
        assert_eq!(DeadlineClass::parse("soon"), None);
        assert!(DeadlineClass::Interactive.weight() > DeadlineClass::Standard.weight());
        assert!(DeadlineClass::Batch.weight() < DeadlineClass::Standard.weight());
        assert_eq!(DeadlineClass::Standard.weight(), 1.0);
    }

    #[test]
    fn query_set_sorts_and_filters() {
        let qs = QuerySet::new(vec![
            spec("zeta", ClassId::Person, &[1]),
            spec("alpha", ClassId::Moped, &[0, 1]),
        ])
        .unwrap();
        assert_eq!(qs.specs()[0].id, "alpha");
        assert_eq!(qs.specs()[1].id, "zeta");
        let on_cam0: Vec<&str> =
            qs.active(CameraId(0), 5.0).map(|(_, s)| s.id.as_str()).collect();
        assert_eq!(on_cam0, vec!["alpha"]);
        let on_cam1: Vec<&str> =
            qs.active(CameraId(1), 5.0).map(|(_, s)| s.id.as_str()).collect();
        assert_eq!(on_cam1, vec!["alpha", "zeta"]);
        assert!(QuerySet::new(vec![
            spec("dup", ClassId::Moped, &[]),
            spec("dup", ClassId::Person, &[]),
        ])
        .is_err());
    }

    #[test]
    fn time_windows_gate_activity() {
        let mut s = spec("windowed", ClassId::Moped, &[0]);
        s.from = 10.0;
        s.until = 20.0;
        let qs = QuerySet::new(vec![s]).unwrap();
        assert_eq!(qs.active(CameraId(0), 5.0).count(), 0);
        assert_eq!(qs.active(CameraId(0), 10.0).count(), 1);
        assert_eq!(qs.active(CameraId(0), 19.9).count(), 1);
        assert_eq!(qs.active(CameraId(0), 20.0).count(), 0);
    }

    #[test]
    fn route_weight_takes_most_demanding_active_query() {
        let mut a = spec("a", ClassId::Moped, &[0]);
        a.deadline = DeadlineClass::Batch;
        let mut b = spec("b", ClassId::Person, &[0]);
        b.deadline = DeadlineClass::Interactive;
        let qs = QuerySet::new(vec![a, b]).unwrap();
        assert_eq!(qs.route_weight(CameraId(0), 1.0), 2.0);
        // No active query on camera 1 -> neutral weight.
        assert_eq!(qs.route_weight(CameraId(1), 1.0), 1.0);
        // A lone batch query really does bid below neutral.
        let mut lone = spec("lone", ClassId::Moped, &[3]);
        lone.deadline = DeadlineClass::Batch;
        let qs = QuerySet::new(vec![lone]).unwrap();
        assert_eq!(qs.route_weight(CameraId(3), 1.0), 0.5);
    }

    #[test]
    fn verdict_encode_decode_roundtrip() {
        for site in ["edge", "cloud", "local", "shed"] {
            let v = QueryVerdict {
                query: "amber-moped".to_string(),
                task: 421,
                t: 17.25,
                positive: site != "cloud",
                confidence: 0.625,
                site,
                latency: 0.375,
            };
            let decoded = decode_query_verdict(&encode_query_verdict(&v)).unwrap();
            assert_eq!(decoded, v);
        }
        assert!(decode_query_verdict(&[1, 0]).is_err());
        let mut bytes = encode_query_verdict(&QueryVerdict {
            query: "q".into(),
            task: 1,
            t: 0.0,
            positive: true,
            confidence: 0.5,
            site: "edge",
            latency: 0.0,
        });
        bytes.push(0); // trailing garbage
        assert!(decode_query_verdict(&bytes).is_err());
    }

    #[test]
    fn jsonl_is_deterministic_and_filtered() {
        let verdicts = vec![
            QueryVerdict {
                query: "a".into(),
                task: 1,
                t: 1.5,
                positive: true,
                confidence: 0.9,
                site: "edge",
                latency: 0.25,
            },
            QueryVerdict {
                query: "b".into(),
                task: 1,
                t: 1.5,
                positive: false,
                confidence: 0.2,
                site: "local",
                latency: 0.25,
            },
        ];
        let a1 = verdicts_jsonl(&verdicts, "a");
        let a2 = verdicts_jsonl(&verdicts, "a");
        assert_eq!(a1, a2);
        assert_eq!(
            a1,
            "{\"query\":\"a\",\"task\":1,\"t\":1.5,\"positive\":true,\"confidence\":0.9,\"site\":\"edge\",\"latency\":0.25}\n"
        );
        assert!(!verdicts_jsonl(&verdicts, "b").contains("\"query\":\"a\""));
        assert_eq!(verdicts_jsonl(&verdicts, "missing"), "");
    }

    #[test]
    fn per_query_reports_in_id_order() {
        let qs = QuerySet::new(vec![
            spec("b", ClassId::Person, &[]),
            spec("a", ClassId::Moped, &[]),
        ])
        .unwrap();
        let verdicts = vec![
            QueryVerdict {
                query: "a".into(),
                task: 1,
                t: 1.0,
                positive: true,
                confidence: 0.9,
                site: "edge",
                latency: 0.2,
            },
            QueryVerdict {
                query: "a".into(),
                task: 2,
                t: 2.0,
                positive: false,
                confidence: 0.5,
                site: "cloud",
                latency: 0.4,
            },
        ];
        let reports = qs.per_query_reports(&verdicts);
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].name, "a");
        assert_eq!(reports[0].kind, "query_run");
        assert_eq!(reports[0].get("verdicts"), Some(2.0));
        assert_eq!(reports[0].get("positives"), Some(1.0));
        assert_eq!(reports[0].get("doubtful_cloud"), Some(1.0));
        assert!((reports[0].get("mean_latency_s").unwrap() - 0.3).abs() < 1e-12);
        assert_eq!(reports[1].name, "b");
        assert_eq!(reports[1].get("verdicts"), Some(0.0));
        assert_eq!(reports[1].get("mean_latency_s"), Some(0.0));
    }

    #[test]
    fn dominant_class_takes_most_demanding_active_query() {
        let mut a = spec("a", ClassId::Moped, &[0]);
        a.deadline = DeadlineClass::Batch;
        let mut b = spec("b", ClassId::Person, &[0]);
        b.deadline = DeadlineClass::Interactive;
        let qs = QuerySet::new(vec![a, b]).unwrap();
        assert_eq!(qs.dominant_class(CameraId(0), 1.0), DeadlineClass::Interactive);
        // No active query -> standard, matching route_weight's 1.0.
        assert_eq!(qs.dominant_class(CameraId(1), 1.0), DeadlineClass::Standard);
        let mut lone = spec("lone", ClassId::Moped, &[3]);
        lone.deadline = DeadlineClass::Batch;
        let qs = QuerySet::new(vec![lone]).unwrap();
        assert_eq!(qs.dominant_class(CameraId(3), 1.0), DeadlineClass::Batch);
    }

    #[test]
    fn per_query_reports_count_shed_separately() {
        let qs = QuerySet::new(vec![spec("a", ClassId::Moped, &[])]).unwrap();
        let answered = QueryVerdict {
            query: "a".into(),
            task: 1,
            t: 1.0,
            positive: true,
            confidence: 0.9,
            site: "edge",
            latency: 0.2,
        };
        let shed = QueryVerdict {
            query: "a".into(),
            task: 2,
            t: 2.0,
            positive: false,
            confidence: 0.5,
            site: "shed",
            latency: 3.0,
        };
        let with_shed = qs.per_query_reports(&[answered.clone(), shed])[0].clone();
        assert_eq!(with_shed.get("shed"), Some(1.0));
        assert_eq!(with_shed.get("verdicts"), Some(1.0), "shed rows are not answers");
        assert_eq!(with_shed.get("negatives"), Some(0.0));
        assert!(
            (with_shed.get("mean_latency_s").unwrap() - 0.2).abs() < 1e-12,
            "shed latency stays out of answer statistics"
        );
        // No shed -> the metric is absent, keeping the schema identical
        // to pre-overload reports.
        let without = qs.per_query_reports(&[answered])[0].clone();
        assert!(without.get("shed").is_none());
    }

    #[test]
    fn admission_model_utilization_scales_with_cameras() {
        let m = model_for(&Config::single_edge());
        assert_eq!(m.utilization(0), 0.0);
        let u1 = m.utilization(1);
        let u4 = m.utilization(4);
        assert!(u1 > 0.0);
        assert!((u4 - 4.0 * u1).abs() < 1e-9, "linear in cameras: {u4} vs {u1}");
    }

    #[test]
    fn registry_rejects_over_headroom_with_named_error() {
        let cfg = Config::single_edge(); // 4 cameras, 1 edge
        let m = model_for(&cfg);
        // Headroom below the single-camera load: nothing fits.
        let tight = QueryRegistry::new(m.clone(), m.utilization(1) * 0.5);
        let err = tight.admit(spec("greedy", ClassId::Moped, &[0]), 0.0).unwrap_err().to_string();
        assert!(err.contains("greedy"), "{err}");
        assert!(err.contains("headroom"), "{err}");
        assert!(tight.is_empty());
        // Headroom for one camera but not two.
        let mid = QueryRegistry::new(m.clone(), m.utilization(1) * 1.5);
        mid.admit(spec("first", ClassId::Moped, &[0]), 0.0).unwrap();
        assert!(mid.admit(spec("second", ClassId::Person, &[1]), 1.0).is_err());
        // Same camera: no new load, fits.
        mid.admit(spec("shared", ClassId::Person, &[0]), 2.0).unwrap();
        assert_eq!(mid.len(), 2);
    }

    #[test]
    fn registry_duplicate_and_unknown_ids() {
        let cfg = Config::single_edge();
        let reg = QueryRegistry::new(model_for(&cfg), 1e9);
        reg.admit(spec("q", ClassId::Moped, &[0]), 0.0).unwrap();
        assert!(reg.admit(spec("q", ClassId::Person, &[1]), 1.0).is_err());
        assert!(reg.retire("nope", 2.0).is_err());
        reg.retire("q", 3.0).unwrap();
        assert!(reg.is_empty());
    }

    #[test]
    fn registry_emits_spans_counters_and_bus_events() {
        let cfg = Config::single_edge();
        let reg = QueryRegistry::new(model_for(&cfg), 1e9);
        let obs = Registry::new();
        let broker = Broker::new();
        let (rx, _id) = broker.subscribe("query/+/admitted", 8);
        let (rx_ret, _id2) = broker.subscribe("query/+/retired", 8);
        reg.attach_registry(obs.clone());
        reg.attach_broker(broker);
        reg.admit(spec("q1", ClassId::Moped, &[0]), 5.0).unwrap();
        reg.retire("q1", 9.0).unwrap();
        assert_eq!(obs.counter("surveiledge_query_admitted_total", &[("query", "q1")]), 1);
        assert_eq!(obs.counter("surveiledge_query_retired_total", &[("query", "q1")]), 1);
        let events = obs.events();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].stage, Stage::QueryAdmit);
        assert_eq!(events[0].detail, "q1");
        assert_eq!(events[0].t, 5.0);
        assert_eq!(events[1].stage, Stage::QueryRetire);
        assert_eq!(events[1].t, 9.0);
        assert_eq!(rx.try_recv().unwrap().topic, "query/q1/admitted");
        assert_eq!(rx_ret.try_recv().unwrap().topic, "query/q1/retired");
    }

    #[test]
    fn prop_admitted_load_never_exceeds_headroom() {
        check("query::admitted_load_under_headroom", |rng, _case| {
            let cfg = Config::homogeneous(); // 12 cameras
            let m = model_for(&cfg);
            let headroom = m.utilization(1) * rng.range_f64(0.5, 14.0);
            let reg = QueryRegistry::new(m, headroom);
            for i in 0..rng.range_usize(1, 10) {
                let n_cams = rng.range_usize(0, 4);
                let cams: Vec<u32> =
                    (0..n_cams).map(|_| rng.range_usize(0, 12) as u32).collect();
                let _ = reg.admit(
                    spec(&format!("q{i}"), ClassId::Moped, &cams),
                    i as f64,
                );
                assert!(
                    reg.projected_load() <= headroom + 1e-9,
                    "load {} > headroom {headroom}",
                    reg.projected_load()
                );
            }
        });
    }

    #[test]
    fn prop_admission_order_independent_when_all_fit() {
        check("query::admission_order_independent", |rng, _case| {
            let cfg = Config::homogeneous();
            let m = model_for(&cfg);
            // Headroom above the whole-fleet load: any subset fits, so
            // every admit order must admit everything.
            let reg_hdr = m.utilization(cfg.total_cameras()) + 1.0;
            let n = rng.range_usize(2, 6);
            let mut specs: Vec<QuerySpec> = (0..n)
                .map(|i| {
                    let cams: Vec<u32> =
                        (0..rng.range_usize(0, 3)).map(|_| rng.range_usize(0, 12) as u32).collect();
                    spec(&format!("q{i}"), ClassId::Moped, &cams)
                })
                .collect();
            let reg_a = QueryRegistry::new(m.clone(), reg_hdr);
            for s in &specs {
                reg_a.admit(s.clone(), 0.0).unwrap();
            }
            rng.shuffle(&mut specs);
            let reg_b = QueryRegistry::new(m, reg_hdr);
            for s in &specs {
                reg_b.admit(s.clone(), 0.0).unwrap();
            }
            assert_eq!(reg_a.snapshot().specs(), reg_b.snapshot().specs());
            assert!((reg_a.projected_load() - reg_b.projected_load()).abs() < 1e-12);
        });
    }

    #[test]
    fn prop_retiring_never_perturbs_other_streams() {
        // Fan the same shared results out through a 3-query set and a
        // 2-query set (one retired); surviving queries' verdicts must be
        // identical — retirement cannot perturb another query's stream.
        check("query::retire_isolation", |rng, _case| {
            let full = QuerySet::new(vec![
                spec("keep-a", ClassId::Moped, &[0]),
                spec("gone", ClassId::Person, &[0]),
                spec("keep-b", ClassId::Moped, &[0, 1]),
            ])
            .unwrap();
            let reduced = QuerySet::new(vec![
                spec("keep-a", ClassId::Moped, &[0]),
                spec("keep-b", ClassId::Moped, &[0, 1]),
            ])
            .unwrap();
            let fan_out = |qs: &QuerySet| -> Vec<QueryVerdict> {
                let mut shared = Rng::new(0xFA42);
                let mut out = Vec::new();
                for task in 0..40u64 {
                    let t = task as f64 * 0.5;
                    // Shared per-class results, independent of the set.
                    let conf_by_class = [shared.f32(), shared.f32()];
                    let oracle_by_class = [shared.bool(0.5), shared.bool(0.5)];
                    let went_cloud = shared.bool(0.3);
                    for (qi, s) in qs.active(CameraId(0), t) {
                        let ci = usize::from(s.object == ClassId::Person);
                        let (positive, site) =
                            s.decide(conf_by_class[ci], oracle_by_class[ci], went_cloud);
                        out.push(QueryVerdict {
                            query: qs.specs()[qi].id.clone(),
                            task,
                            t,
                            positive,
                            confidence: conf_by_class[ci],
                            site,
                            latency: 0.1,
                        });
                    }
                }
                out
            };
            let _ = rng.next_u64();
            let before = fan_out(&full);
            let after = fan_out(&reduced);
            for id in ["keep-a", "keep-b"] {
                assert_eq!(verdicts_jsonl(&before, id), verdicts_jsonl(&after, id), "{id}");
            }
        });
    }

    #[test]
    fn query_file_parses_presets_defaults_and_rejects_unknown_keys() {
        let text = r#"
[scenario]
duration = 30.0
seed = 11

[edges]
speed = [1.0]
cameras = [2]

[admission]
headroom = 0.9

[[query]]
id = "amber-moped"
object = "moped"
cameras = [0, 1]
deadline = "interactive"

[[query]]
id = "night-person"
object = "person"
alpha = 0.9
beta = 0.05
from = 5.0
until = 25.0
"#;
        let qf = QueryFile::parse(text).unwrap();
        assert_eq!(qf.headroom, 0.9);
        assert_eq!(qf.queries.len(), 2);
        assert_eq!(qf.queries[0].id, "amber-moped");
        assert_eq!(qf.queries[0].object, ClassId::Moped);
        assert_eq!(qf.queries[0].deadline, DeadlineClass::Interactive);
        assert_eq!(qf.queries[0].alpha, 0.8); // default
        assert_eq!(qf.queries[1].cameras, Vec::<CameraId>::new()); // all
        assert_eq!(qf.queries[1].until, 25.0);
        assert_eq!(qf.cfg.edges[0].cameras, 2);

        let bad = "[[query]]\nid = \"q\"\nobject = \"moped\"\nprioritee = 3\n";
        let err = QueryFile::parse(bad).unwrap_err().to_string();
        assert!(err.contains("prioritee"), "{err}");
        assert!(err.contains("\"q\""), "{err}");
        assert!(err.contains("expected one of"), "{err}");

        let dup = "[[query]]\nid = \"q\"\nobject = \"moped\"\n[[query]]\nid = \"q\"\nobject = \"person\"\n";
        assert!(QueryFile::parse(dup).unwrap_err().to_string().contains("duplicate"));

        let bad_obj = "[[query]]\nid = \"q\"\nobject = \"dragon\"\n";
        assert!(QueryFile::parse(bad_obj).unwrap_err().to_string().contains("dragon"));

        let bad_deadline = "[[query]]\nid = \"q\"\nobject = \"moped\"\ndeadline = \"soon\"\n";
        assert!(QueryFile::parse(bad_deadline).unwrap_err().to_string().contains("soon"));
    }

    #[test]
    fn write_results_creates_dir_and_one_file_per_query() {
        let dir = std::env::temp_dir()
            .join(format!("surveiledge_query_{}", std::process::id()))
            .join("nested/deep");
        let _ = std::fs::remove_dir_all(&dir);
        let qs = QuerySet::new(vec![
            spec("a", ClassId::Moped, &[]),
            spec("b", ClassId::Person, &[]),
        ])
        .unwrap();
        let verdicts = vec![QueryVerdict {
            query: "a".into(),
            task: 7,
            t: 3.0,
            positive: true,
            confidence: 0.95,
            site: "edge",
            latency: 0.5,
        }];
        let paths = write_results(&dir, &verdicts, qs.specs()).unwrap();
        assert_eq!(paths.len(), 2);
        assert!(paths[0].ends_with("query_a.jsonl"));
        let a = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(a.contains("\"task\":7"));
        // Empty stream still gets a (comparable) empty file.
        assert_eq!(std::fs::read_to_string(&paths[1]).unwrap(), "");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
