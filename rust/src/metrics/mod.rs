//! Query-quality and performance metrics (paper §V-A).
//!
//! * [`Confusion`] — precision, recall, and the paper's F_λ score (they
//!   report F₂, weighting recall over precision).
//! * [`LatencyRecorder`] — per-frame query latencies: mean/percentiles,
//!   PDF histograms (Figs. 6–8 (a)), and the raw per-frame series
//!   (Figs. 6–8 (b)–(d)).
//! * [`BandwidthMeter`] — bytes uploaded to the Cloud per scheme.
//! * table renderers used by the bench harness to print paper-style rows.

use std::collections::HashMap;

/// Raw confusion counts, read through [`Confusion::counts`] — one
/// accessor instead of four public fields (and no more `fn_` keyword
/// workaround in the public surface).
#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct ConfusionCounts {
    pub true_positives: u64,
    pub false_positives: u64,
    pub true_negatives: u64,
    pub false_negatives: u64,
}

/// Binary confusion counts for query answers. Record-only: counts go in
/// via [`Confusion::record`] (or [`Confusion::from_counts`]) and come
/// back out via [`Confusion::counts`].
#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct Confusion {
    tp: u64,
    fp: u64,
    tn: u64,
    fn_: u64,
}

impl Confusion {
    /// Build from known raw counts (tests, replay from exports).
    pub fn from_counts(
        true_positives: u64,
        false_positives: u64,
        true_negatives: u64,
        false_negatives: u64,
    ) -> Confusion {
        Confusion {
            tp: true_positives,
            fp: false_positives,
            tn: true_negatives,
            fn_: false_negatives,
        }
    }

    pub fn record(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    /// Snapshot of the raw counts.
    pub fn counts(&self) -> ConfusionCounts {
        ConfusionCounts {
            true_positives: self.tp,
            false_positives: self.fp,
            true_negatives: self.tn,
            false_negatives: self.fn_,
        }
    }

    pub fn false_negatives(&self) -> u64 {
        self.fn_
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            // No positive predictions: undefined; report 1 so F-score is
            // driven by recall (conventional for sparse queries).
            return 1.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 1.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    /// F_λ = (1+λ²)·p·r / (λ²·p + r) — the paper's accuracy metric with
    /// λ=2 (recall-weighted).
    pub fn f_score(&self, lambda: f64) -> f64 {
        let p = self.precision();
        let r = self.recall();
        let l2 = lambda * lambda;
        if p <= 0.0 && r <= 0.0 {
            return 0.0;
        }
        (1.0 + l2) * p * r / (l2 * p + r)
    }

    pub fn f2(&self) -> f64 {
        self.f_score(2.0)
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 1.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }
}

/// Latency series + summary statistics.
#[derive(Clone, Default, Debug)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder { samples: Vec::new() }
    }

    pub fn record(&mut self, latency: f64) {
        if latency.is_finite() && latency >= 0.0 {
            self.samples.push(latency);
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw per-frame series in arrival order (Figs. 6–8 line plots).
    pub fn series(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn variance(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }

    /// Percentile by nearest-rank (q in [0,1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((q.clamp(0.0, 1.0)) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    /// Empirical PDF over `bins` equal-width bins on [0, max] — the data
    /// behind Figs. 6–8 (a). Returns (bin_centres, densities).
    pub fn pdf(&self, bins: usize) -> (Vec<f64>, Vec<f64>) {
        let bins = bins.max(1);
        if self.samples.is_empty() {
            return (vec![0.0; bins], vec![0.0; bins]);
        }
        let hi = self.max().max(1e-9);
        let width = hi / bins as f64;
        let mut counts = vec![0usize; bins];
        for &s in &self.samples {
            let b = ((s / width) as usize).min(bins - 1);
            counts[b] += 1;
        }
        let n = self.samples.len() as f64;
        let centres = (0..bins).map(|i| (i as f64 + 0.5) * width).collect();
        let dens = counts.iter().map(|&c| c as f64 / (n * width)).collect();
        (centres, dens)
    }
}

/// Upload-bandwidth accounting, per destination.
#[derive(Clone, Default, Debug)]
pub struct BandwidthMeter {
    by_link: HashMap<String, u64>,
}

impl BandwidthMeter {
    pub fn new() -> BandwidthMeter {
        BandwidthMeter::default()
    }

    pub fn add(&mut self, link: &str, bytes: u64) {
        *self.by_link.entry(link.to_string()).or_insert(0) += bytes;
    }

    pub fn total(&self) -> u64 {
        self.by_link.values().sum()
    }

    /// Bytes on links whose name contains "cloud" — the paper's
    /// "bandwidth cost" is edge→cloud upload volume.
    pub fn cloud_bytes(&self) -> u64 {
        self.by_link
            .iter()
            .filter(|(k, _)| k.contains("cloud"))
            .map(|(_, v)| *v)
            .sum()
    }

    pub fn mb(bytes: u64) -> f64 {
        bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Recovery accounting for fault-injection runs (chaos scenarios; see
/// `crate::faults`). All counters are 0 for a fault-free run.
#[derive(Clone, Copy, Default, Debug, PartialEq)]
pub struct FaultStats {
    /// Failed deliveries re-sent after an ack timeout (dropped in transit
    /// or addressed to a node that was down).
    pub retried: u64,
    /// Tasks swept off a crashed node's queue and re-allocated.
    pub rerouted: u64,
    /// Tasks answered with an edge-local verdict because the cloud was
    /// unreachable or the retry budget ran out (graceful degradation).
    pub degraded: u64,
    /// Tasks that never received a verdict by the end of the run.
    pub lost: u64,
    /// Tasks *explicitly* shed by overload control (bounded-queue
    /// overflow, ladder shed level, or an exhausted retry budget). Shed
    /// tasks are accounted, not lost: each gets a `shed` span and a
    /// per-query `site = "shed"` record.
    pub shed: u64,
    /// Seconds from the first crash to its failover sweep (0.0 when no
    /// sweep re-queued anything).
    pub time_to_reroute: f64,
}

impl FaultStats {
    /// Did the run see any fault-recovery activity at all?
    pub fn any(&self) -> bool {
        self.retried + self.rerouted + self.degraded + self.lost + self.shed > 0
    }

    /// Contribute the recovery metrics to a [`crate::obs::Report`] (the
    /// one stable schema every consumer reads results through).
    /// `faults_shed` appears only when overload control actually shed
    /// something, so reports from runs without an `[overload]` block stay
    /// byte-identical to pre-overload builds.
    pub fn fill_report(&self, r: &mut crate::obs::Report) {
        r.push("faults_retried", self.retried as f64);
        r.push("faults_rerouted", self.rerouted as f64);
        r.push("faults_degraded", self.degraded as f64);
        r.push("faults_lost", self.lost as f64);
        if self.shed > 0 {
            r.push("faults_shed", self.shed as f64);
        }
        r.push("time_to_reroute_s", self.time_to_reroute);
    }
}

/// One row of a paper-style results table (Tables II–IV).
#[derive(Clone, Debug)]
pub struct SchemeRow {
    pub scheme: String,
    /// F2 accuracy vs the ground-truth CNN, in [0,1].
    pub accuracy: f64,
    pub avg_latency: f64,
    pub bandwidth_mb: f64,
}

/// Render rows as the paper's table layout (markdown).
pub fn render_table(title: &str, rows: &[SchemeRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("### {title}\n\n"));
    out.push_str("| scheme | accuracy | average latency | bandwidth cost |\n");
    out.push_str("|--------|----------|-----------------|----------------|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.2}% | {:.3}s | {:.1} MB |\n",
            r.scheme,
            r.accuracy * 100.0,
            r.avg_latency,
            r.bandwidth_mb
        ));
    }
    out
}

/// Render a PDF or series as CSV (figure data dumps).
pub fn render_csv(headers: &[&str], columns: &[&[f64]]) -> String {
    assert!(!columns.is_empty());
    let rows = columns.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut out = headers.join(",");
    out.push('\n');
    for i in 0..rows {
        let line: Vec<String> = columns
            .iter()
            .map(|c| c.get(i).map_or(String::new(), |v| format!("{v:.6}")))
            .collect();
        out.push_str(&line.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    #[test]
    fn fault_stats_default_is_quiet() {
        let f = FaultStats::default();
        assert!(!f.any());
        assert_eq!(
            f,
            FaultStats { retried: 0, rerouted: 0, degraded: 0, lost: 0, shed: 0, time_to_reroute: 0.0 }
        );
        assert!(FaultStats { retried: 1, ..FaultStats::default() }.any());
        assert!(FaultStats { lost: 1, ..FaultStats::default() }.any());
        assert!(FaultStats { shed: 1, ..FaultStats::default() }.any());
    }

    #[test]
    fn fill_report_emits_shed_only_when_nonzero() {
        let mut quiet = crate::obs::Report::new("scheme_run", "test");
        FaultStats::default().fill_report(&mut quiet);
        assert!(quiet.get("faults_shed").is_none(), "no-shed reports stay schema-identical");
        let mut shed = crate::obs::Report::new("scheme_run", "test");
        FaultStats { shed: 3, ..FaultStats::default() }.fill_report(&mut shed);
        assert_eq!(shed.get("faults_shed"), Some(3.0));
    }

    #[test]
    fn confusion_counts() {
        let mut c = Confusion::default();
        c.record(true, true);
        c.record(true, false);
        c.record(false, true);
        c.record(false, false);
        let k = c.counts();
        assert_eq!(
            (k.true_positives, k.false_positives, k.false_negatives, k.true_negatives),
            (1, 1, 1, 1)
        );
        assert_eq!(c.false_negatives(), 1);
        assert_eq!(c, Confusion::from_counts(1, 1, 1, 1));
        assert_eq!(c.total(), 4);
        assert!((c.precision() - 0.5).abs() < 1e-12);
        assert!((c.recall() - 0.5).abs() < 1e-12);
        assert!((c.accuracy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_equals_harmonic_mean() {
        let c = Confusion::from_counts(8, 2, 5, 4);
        let p = c.precision();
        let r = c.recall();
        let f1 = c.f_score(1.0);
        assert!((f1 - 2.0 * p * r / (p + r)).abs() < 1e-12);
    }

    #[test]
    fn f2_weights_recall() {
        // High precision / low recall should score worse under F2 than the
        // mirrored case.
        let high_p = Confusion::from_counts(5, 0, 10, 5); // p=1, r=0.5
        let high_r = Confusion::from_counts(10, 10, 0, 0); // p=0.5, r=1
        assert!(high_r.f2() > high_p.f2());
    }

    #[test]
    fn perfect_scores() {
        let c = Confusion::from_counts(10, 0, 10, 0);
        assert_eq!(c.f2(), 1.0);
        assert_eq!(c.accuracy(), 1.0);
    }

    #[test]
    fn prop_fscore_bounded() {
        check("fscore_bounded", |rng, _| {
            let c = Confusion::from_counts(
                rng.range_usize(0, 100) as u64,
                rng.range_usize(0, 100) as u64,
                rng.range_usize(0, 100) as u64,
                rng.range_usize(0, 100) as u64,
            );
            for lambda in [0.5, 1.0, 2.0] {
                let f = c.f_score(lambda);
                assert!((0.0..=1.0).contains(&f), "F_{lambda} = {f} for {c:?}");
            }
        });
    }

    #[test]
    fn latency_stats() {
        let mut r = LatencyRecorder::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.record(v);
        }
        assert_eq!(r.len(), 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.variance() - 1.25).abs() < 1e-12);
        assert_eq!(r.max(), 4.0);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 4.0);
    }

    #[test]
    fn latency_rejects_garbage() {
        let mut r = LatencyRecorder::new();
        r.record(f64::NAN);
        r.record(-1.0);
        r.record(f64::INFINITY);
        assert!(r.is_empty());
    }

    #[test]
    fn pdf_integrates_to_one() {
        let mut r = LatencyRecorder::new();
        let mut rng = crate::testkit::Rng::new(1);
        for _ in 0..500 {
            r.record(rng.lognormal3(-0.5, 0.5, 0.1));
        }
        let (centres, dens) = r.pdf(20);
        assert_eq!(centres.len(), 20);
        let width = centres[1] - centres[0];
        let integral: f64 = dens.iter().map(|d| d * width).sum();
        assert!((integral - 1.0).abs() < 1e-6, "integral {integral}");
    }

    #[test]
    fn bandwidth_cloud_accounting() {
        let mut bw = BandwidthMeter::new();
        bw.add("edge1->cloud", 1024);
        bw.add("edge2->cloud", 2048);
        bw.add("edge1->edge2", 4096);
        assert_eq!(bw.cloud_bytes(), 3072);
        assert_eq!(bw.total(), 7168);
        assert!((BandwidthMeter::mb(1024 * 1024) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = vec![
            SchemeRow { scheme: "SurveilEdge".into(), accuracy: 0.884, avg_latency: 1.018, bandwidth_mb: 1129.5 },
            SchemeRow { scheme: "cloud-only".into(), accuracy: 1.0, avg_latency: 14.823, bandwidth_mb: 3400.3 },
        ];
        let t = render_table("Table II", &rows);
        assert!(t.contains("SurveilEdge"));
        assert!(t.contains("88.40%"));
        assert!(t.contains("14.823s"));
    }

    #[test]
    fn csv_ragged_columns() {
        let a = [1.0, 2.0];
        let b = [3.0];
        let csv = render_csv(&["x", "y"], &[&a, &b]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "x,y");
        assert!(lines[2].starts_with("2.0"));
        assert!(lines[2].ends_with(','));
    }
}
