//! The query coordinator: offline stage + online stage (paper §III-B).
//!
//! **Offline stage** ([`OfflineStage`]): collect leisure-time footage from
//! every camera, detect moving objects (frame difference), label the crops
//! with the high-accuracy cloud CNN, build per-camera *proportion vectors*,
//! K-Means them into context clusters, and assemble one labeled training
//! dataset per cluster (the paper's Fig. 2(b) left half, entirely at the
//! Cloud).
//!
//! **Online stage** ([`online_fine_tune`]): when a query arrives, select
//! positives/negatives from the query's cluster dataset (negatives sampled
//! proportionally to the cluster profile, §IV-B), fine-tune the CQ-specific
//! CNN from pretrained weights, and deploy it to the cluster's edges.

use crate::cluster::{kmeans, Clustering, Profile};
use crate::detect::{detect, DetectConfig};
use crate::runtime::service::{FineTuneResult, ServiceHandle};
use crate::testkit::Rng;
use crate::types::{CameraId, ClassId, Image, NUM_CLASSES};
use crate::video::Camera;

/// One labeled crop in a context-specific dataset.
#[derive(Clone, Debug)]
pub struct LabeledCrop {
    pub camera: CameraId,
    /// Label assigned by the cloud CNN (the paper's labeling oracle).
    pub label: ClassId,
    /// Crop resized to CNN input resolution.
    pub crop: Image,
}

/// Per-cluster training dataset.
#[derive(Clone, Debug, Default)]
pub struct ClusterDataset {
    pub crops: Vec<LabeledCrop>,
    /// The cluster centre = cluster profile (proportion vector).
    pub profile: [f64; NUM_CLASSES],
}

/// Output of the offline stage.
pub struct OfflineStage {
    pub profiles: Vec<Profile>,
    pub clustering: Clustering,
    pub datasets: Vec<ClusterDataset>,
}

impl OfflineStage {
    /// Cluster index serving camera `cam`.
    pub fn cluster_of_camera(&self, cam: CameraId) -> Option<usize> {
        self.profiles
            .iter()
            .position(|p| p.camera == cam)
            .map(|i| self.clustering.assignment[i])
    }
}

/// Parameters of the offline collection pass.
#[derive(Clone, Debug)]
pub struct OfflineConfig {
    /// Seconds of leisure-time footage sampled per camera.
    pub duration: f64,
    /// Sampling interval (seconds per analysed frame triplet).
    pub interval: f64,
    pub detect: DetectConfig,
    /// Number of clusters k (paper: K-Means split their 14 cameras in 2).
    pub k: usize,
    pub seed: u64,
}

impl Default for OfflineConfig {
    fn default() -> OfflineConfig {
        OfflineConfig {
            duration: 120.0,
            interval: 1.0,
            detect: DetectConfig::default(),
            k: 2,
            seed: 17,
        }
    }
}

/// Run the offline stage against live cameras, labeling crops with the
/// cloud CNN via the inference service.
pub fn offline_stage(
    cameras: &mut [Camera],
    service: &ServiceHandle,
    cfg: &OfflineConfig,
) -> crate::Result<OfflineStage> {
    let mut per_camera_counts: Vec<[usize; NUM_CLASSES]> = vec![[0; NUM_CLASSES]; cameras.len()];
    let mut crops_by_camera: Vec<Vec<LabeledCrop>> = vec![Vec::new(); cameras.len()];

    for (ci, cam) in cameras.iter_mut().enumerate() {
        let mut t = cfg.interval;
        let mut prev = cam.frame_at(0.0);
        let mut cur = cam.frame_at(cfg.interval);
        while t + cfg.interval <= cfg.duration {
            let nxt = cam.frame_at(t + cfg.interval);
            for det in detect(&prev.image, &cur.image, &nxt.image, &cfg.detect) {
                let bb = det.bbox.expand(cfg.detect.margin, cur.image.h, cur.image.w);
                let crop = cur
                    .image
                    .crop(bb.y0, bb.x0, bb.y1, bb.x1)
                    .resize(cfg.detect.crop_size, cfg.detect.crop_size);
                // Label with the high-accuracy CNN (the paper uses
                // YOLOv3+ResNet-152 for exactly this).
                let probs = service.cloud_infer(crop.data.clone())?;
                let label = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .and_then(|(i, _)| ClassId::from_index(i));
                if let Some(label) = label {
                    per_camera_counts[ci][label.index()] += 1;
                    crops_by_camera[ci].push(LabeledCrop {
                        camera: cam.spec.camera,
                        label,
                        crop,
                    });
                }
            }
            prev = cur;
            cur = nxt;
            t += cfg.interval;
        }
    }

    let profiles: Vec<Profile> = cameras
        .iter()
        .zip(per_camera_counts.iter())
        .map(|(cam, counts)| Profile::from_counts(cam.spec.camera, counts))
        .collect();
    let clustering = kmeans(&profiles, cfg.k.min(profiles.len().max(1)), cfg.seed);

    let mut datasets: Vec<ClusterDataset> = clustering
        .centres
        .iter()
        .map(|c| ClusterDataset { crops: Vec::new(), profile: *c })
        .collect();
    for (ci, crops) in crops_by_camera.into_iter().enumerate() {
        let cluster = clustering.assignment[ci];
        datasets[cluster].crops.extend(crops);
    }
    Ok(OfflineStage { profiles, clustering, datasets })
}

/// Select a fine-tuning set per the paper's §IV-B rule: positives are the
/// query class; negatives are sampled proportionally to the cluster
/// profile (commonly-seen objects get more negative examples). Returns
/// (pixels, labels) ready for the train artifact.
pub fn select_training_set(
    dataset: &ClusterDataset,
    query: ClassId,
    target: usize,
    pos_frac: f64,
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let positives: Vec<&LabeledCrop> =
        dataset.crops.iter().filter(|c| c.label == query).collect();
    let mut negatives_by_class: Vec<Vec<&LabeledCrop>> = vec![Vec::new(); NUM_CLASSES];
    for c in dataset.crops.iter().filter(|c| c.label != query) {
        negatives_by_class[c.label.index()].push(c);
    }
    // Negative class weights = cluster profile with the query zeroed.
    let mut weights = dataset.profile;
    weights[query.index()] = 0.0;
    for (i, w) in weights.iter_mut().enumerate() {
        if negatives_by_class[i].is_empty() {
            *w = 0.0;
        }
    }
    let any_negatives = weights.iter().any(|&w| w > 0.0);

    let mut pixels = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..target {
        let take_pos = !positives.is_empty() && (rng.bool(pos_frac) || !any_negatives);
        let crop = if take_pos {
            positives[rng.range_usize(0, positives.len())]
        } else if any_negatives {
            let cls = rng.weighted(&weights);
            let pool = &negatives_by_class[cls];
            pool[rng.range_usize(0, pool.len())]
        } else {
            continue;
        };
        pixels.extend_from_slice(&crop.crop.data);
        labels.push((crop.label == query) as i32);
    }
    (pixels, labels)
}

/// Online stage: fine-tune the CQ-specific CNN for (cluster, query) and
/// deploy it to the given edges. Returns the fine-tune telemetry.
pub fn online_fine_tune(
    service: &ServiceHandle,
    dataset: &ClusterDataset,
    query: ClassId,
    edges: &[u32],
    steps: usize,
    seed: u64,
) -> crate::Result<FineTuneResult> {
    let (pixels, labels) = select_training_set(dataset, query, 256, 0.5, seed);
    anyhow::ensure!(
        labels.len() >= 32,
        "cluster dataset too small to fine-tune ({} usable samples)",
        labels.len()
    );
    let pos = labels.iter().filter(|&&l| l == 1).count();
    anyhow::ensure!(
        pos >= 4 && pos <= labels.len() - 4,
        "cluster dataset lacks class balance for query {query} \
         ({pos}/{} positive): collect more leisure-time footage first",
        labels.len()
    );
    let result = service.fine_tune(pixels, labels, steps, 0.005, false)?;
    for &e in edges {
        service.deploy_edge(e, result.params.clone())?;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn crop_of(v: f32) -> Image {
        Image::filled(32, 32, [v, v, v])
    }

    fn demo_dataset() -> ClusterDataset {
        let mut ds = ClusterDataset {
            crops: Vec::new(),
            profile: [0.4, 0.1, 0.1, 0.2, 0.05, 0.05, 0.05, 0.05],
        };
        for i in 0..30 {
            ds.crops.push(LabeledCrop {
                camera: CameraId(0),
                label: ClassId::Moped,
                crop: crop_of(i as f32 / 30.0),
            });
        }
        for i in 0..50 {
            ds.crops.push(LabeledCrop {
                camera: CameraId(1),
                label: ClassId::Car,
                crop: crop_of(0.5 + i as f32 / 100.0),
            });
        }
        for i in 0..10 {
            ds.crops.push(LabeledCrop {
                camera: CameraId(1),
                label: ClassId::Dog,
                crop: crop_of(0.9 - i as f32 / 100.0),
            });
        }
        ds
    }

    #[test]
    fn training_set_is_balanced_and_labeled() {
        let ds = demo_dataset();
        let (pixels, labels) = select_training_set(&ds, ClassId::Moped, 200, 0.5, 3);
        assert_eq!(pixels.len(), labels.len() * 32 * 32 * 3);
        let pos = labels.iter().filter(|&&l| l == 1).count();
        let frac = pos as f64 / labels.len() as f64;
        assert!((0.35..0.65).contains(&frac), "positive fraction {frac}");
    }

    #[test]
    fn negatives_follow_cluster_profile() {
        // Car weight (0.4) >> dog weight (0.05): car negatives dominate.
        // Labels only tell pos/neg, so count via pixel values: cars were
        // rendered in [0.5, 1.0), dogs in (0.8, 0.9] — instead, rely on
        // the weighting statistically by rebuilding with distinct classes.
        let ds = demo_dataset();
        let mut rng = Rng::new(5);
        let mut weights = ds.profile;
        weights[ClassId::Moped.index()] = 0.0;
        // Only car and dog pools are non-empty.
        let mut cars = 0;
        let mut dogs = 0;
        for _ in 0..2000 {
            let mut w = weights;
            for (i, wi) in w.iter_mut().enumerate() {
                if i != ClassId::Car.index() && i != ClassId::Dog.index() {
                    *wi = 0.0;
                }
            }
            match rng.weighted(&w) {
                i if i == ClassId::Car.index() => cars += 1,
                i if i == ClassId::Dog.index() => dogs += 1,
                _ => {}
            }
        }
        assert!(cars > dogs * 4, "profile weighting broken: {cars} vs {dogs}");
    }

    #[test]
    fn training_set_without_negatives_is_all_positive() {
        let mut ds = demo_dataset();
        ds.crops.retain(|c| c.label == ClassId::Moped);
        let (_, labels) = select_training_set(&ds, ClassId::Moped, 64, 0.5, 7);
        assert!(!labels.is_empty());
        assert!(labels.iter().all(|&l| l == 1));
    }

    #[test]
    fn training_set_empty_dataset() {
        let ds = ClusterDataset::default();
        let (pixels, labels) = select_training_set(&ds, ClassId::Moped, 64, 0.5, 7);
        assert!(pixels.is_empty() && labels.is_empty());
    }

    #[test]
    fn offline_config_defaults() {
        let c = OfflineConfig::default();
        assert_eq!(c.k, 2);
        assert!(c.duration > 0.0);
    }
}
