//! Overload control (DESIGN.md §11): bounded queues with deadline-aware
//! shedding, an uplink circuit breaker, and a degradation ladder.
//!
//! The paper's controller (eqs. 8–9) and allocator (eq. 7) trade accuracy
//! for latency under *steady* load, but nothing bounds a backlog once the
//! offered load exceeds capacity: a camera burst or a slow-node window
//! grows queues without limit and interactive queries silently blow their
//! deadlines. This module adds the missing layer between admission
//! control (PR 9) and fault tolerance (PR 2):
//!
//! * [`OverloadConfig`] — the `[overload]` TOML block: queue capacities,
//!   breaker thresholds, ladder thresholds, burst windows. A config
//!   without the block leaves `enabled == false` and every consumer
//!   treats the whole subsystem as absent — existing runs stay
//!   byte-identical.
//! * [`CircuitBreaker`] — per-uplink closed → open → half-open state
//!   machine over ack-timeouts/queue-full failures, with a doubling
//!   open-dwell (hysteresis) so an oscillating fault plan cannot make it
//!   flap.
//! * [`DegradationLadder`] — queue-pressure-driven response levels:
//!   subsample first, then edge-local verdicts (PR 2's degrade path),
//!   then shedding; recovery steps back down one level at a time and only
//!   after sustained slack.
//! * [`shed_victim`] — the deadline-class-aware shed policy: batch sheds
//!   first, then standard; interactive is shed-last.
//!
//! Everything here is pure state fed with simulated (or wall) time — no
//! RNG, no clock reads — so both substrates drive it deterministically.

use crate::faults::BurstWindow;
use crate::query::DeadlineClass;

/// Circuit-breaker thresholds.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failures (ack timeout / queue full) that trip the
    /// breaker open.
    pub trip_after: u32,
    /// Base open dwell (seconds) before the breaker half-opens to probe.
    pub cooldown: f64,
    /// Hysteresis cap: each failed probe doubles the dwell up to here, so
    /// a persistently flapping uplink is probed ever more rarely.
    pub cooldown_max: f64,
    /// Consecutive half-open probe successes required to close again.
    pub probe_successes: u32,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig { trip_after: 3, cooldown: 2.0, cooldown_max: 16.0, probe_successes: 2 }
    }
}

/// Breaker state: `Closed` (traffic flows), `Open` (uplink shunned),
/// `HalfOpen` (probing).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// A state-machine edge actually taken — the caller turns these into
/// `circuit_open` / `circuit_probe` / `circuit_close` span events.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Transition {
    /// Closed → Open or HalfOpen → Open.
    Opened,
    /// Open → HalfOpen (dwell elapsed; probing starts).
    HalfOpened,
    /// HalfOpen → Closed (probes succeeded; dwell resets).
    Closed,
}

/// Per-uplink circuit breaker. Transitions only along
/// closed → open → half-open → {closed, open}; the open dwell doubles on
/// every failed probe (up to [`BreakerConfig::cooldown_max`]) so the
/// machine cannot flap under an oscillating fault plan.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    /// Consecutive failures while closed.
    failures: u32,
    /// Consecutive probe successes while half-open.
    successes: u32,
    opened_at: f64,
    /// Current open dwell (grows ×2 per failed probe, capped).
    dwell: f64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            state: BreakerState::Closed,
            failures: 0,
            successes: 0,
            opened_at: 0.0,
            dwell: cfg.cooldown,
            cfg,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Current open dwell (diagnostics; grows under hysteresis).
    pub fn dwell(&self) -> f64 {
        self.dwell
    }

    /// Advance time: an open breaker whose dwell has elapsed half-opens.
    pub fn poll(&mut self, t: f64) -> Option<Transition> {
        if self.state == BreakerState::Open && t >= self.opened_at + self.dwell {
            self.state = BreakerState::HalfOpen;
            self.successes = 0;
            return Some(Transition::HalfOpened);
        }
        None
    }

    /// May traffic use the guarded path at `t`? Polls first, so an
    /// expired dwell lets a probe through. Returns the transition taken
    /// (if any) alongside the verdict.
    pub fn allows(&mut self, t: f64) -> (bool, Option<Transition>) {
        let tr = self.poll(t);
        (self.state != BreakerState::Open, tr)
    }

    /// An ack arrived (delivery succeeded).
    pub fn on_success(&mut self, t: f64) -> Option<Transition> {
        let _ = self.poll(t);
        match self.state {
            BreakerState::Closed => {
                self.failures = 0;
                None
            }
            BreakerState::HalfOpen => {
                self.successes += 1;
                if self.successes >= self.cfg.probe_successes.max(1) {
                    self.state = BreakerState::Closed;
                    self.failures = 0;
                    self.dwell = self.cfg.cooldown;
                    Some(Transition::Closed)
                } else {
                    None
                }
            }
            // A straggler ack from before the trip: ignored.
            BreakerState::Open => None,
        }
    }

    /// An ack timeout / queue-full failure on the guarded path.
    pub fn on_failure(&mut self, t: f64) -> Option<Transition> {
        let _ = self.poll(t);
        match self.state {
            BreakerState::Closed => {
                self.failures += 1;
                if self.failures >= self.cfg.trip_after.max(1) {
                    self.trip(t);
                    Some(Transition::Opened)
                } else {
                    None
                }
            }
            BreakerState::HalfOpen => {
                // Failed probe: reopen and back the dwell off (hysteresis).
                self.dwell = (self.dwell * 2.0).min(self.cfg.cooldown_max.max(self.cfg.cooldown));
                self.trip(t);
                Some(Transition::Opened)
            }
            BreakerState::Open => None,
        }
    }

    fn trip(&mut self, t: f64) {
        self.state = BreakerState::Open;
        self.opened_at = t;
        self.failures = 0;
        self.successes = 0;
    }
}

/// Degradation-ladder response level, ordered by severity.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum LoadLevel {
    /// No overload response.
    Normal,
    /// Thin the offered load: drop a deterministic fraction of detections
    /// before they become tasks.
    Subsample,
    /// Answer doubtful crops at the edge instead of uploading (PR 2's
    /// degrade path, now driven by pressure instead of a dead cloud).
    EdgeLocal,
    /// Shed batch-class tasks at admission (bounded queues shed on
    /// overflow at every level).
    Shed,
}

impl LoadLevel {
    pub fn as_str(self) -> &'static str {
        match self {
            LoadLevel::Normal => "normal",
            LoadLevel::Subsample => "subsample",
            LoadLevel::EdgeLocal => "edge_local",
            LoadLevel::Shed => "shed",
        }
    }

    fn step_down(self) -> LoadLevel {
        match self {
            LoadLevel::Normal | LoadLevel::Subsample => LoadLevel::Normal,
            LoadLevel::EdgeLocal => LoadLevel::Subsample,
            LoadLevel::Shed => LoadLevel::EdgeLocal,
        }
    }
}

/// Ladder thresholds over the queue-pressure signal (pressure = worst
/// queue-occupancy fraction across the edge's node queue and uplink).
#[derive(Clone, Copy, Debug)]
pub struct LadderConfig {
    /// Pressure at or above `up[k]` escalates to level `k+1`
    /// (Subsample / EdgeLocal / Shed). Must be non-decreasing.
    pub up: [f64; 3],
    /// Pressure at or below this counts as slack.
    pub slack: f64,
    /// Seconds of *sustained* slack required per step back down.
    pub sustain: f64,
}

impl Default for LadderConfig {
    fn default() -> LadderConfig {
        LadderConfig { up: [0.5, 0.75, 0.9], slack: 0.35, sustain: 5.0 }
    }
}

/// The per-edge degradation ladder: escalates immediately when pressure
/// crosses a threshold, steps down one level at a time only after
/// [`LadderConfig::sustain`] seconds of uninterrupted slack.
#[derive(Clone, Debug)]
pub struct DegradationLadder {
    cfg: LadderConfig,
    level: LoadLevel,
    slack_since: Option<f64>,
}

impl DegradationLadder {
    pub fn new(cfg: LadderConfig) -> DegradationLadder {
        DegradationLadder { cfg, level: LoadLevel::Normal, slack_since: None }
    }

    pub fn level(&self) -> LoadLevel {
        self.level
    }

    /// Feed one pressure observation at time `t`; returns the (possibly
    /// new) level. Escalation is immediate; de-escalation needs sustained
    /// slack and moves one rung per sustain window.
    pub fn observe(&mut self, pressure: f64, t: f64) -> LoadLevel {
        let target = if pressure >= self.cfg.up[2] {
            LoadLevel::Shed
        } else if pressure >= self.cfg.up[1] {
            LoadLevel::EdgeLocal
        } else if pressure >= self.cfg.up[0] {
            LoadLevel::Subsample
        } else {
            LoadLevel::Normal
        };
        if target > self.level {
            self.level = target;
            self.slack_since = None;
        } else if pressure <= self.cfg.slack {
            match self.slack_since {
                None => self.slack_since = Some(t),
                Some(since) if t - since >= self.cfg.sustain => {
                    if self.level > LoadLevel::Normal {
                        self.level = self.level.step_down();
                    }
                    // Restart the window: one rung per sustain period.
                    self.slack_since = Some(t);
                }
                Some(_) => {}
            }
        } else {
            // Pressure between slack and the current level's threshold:
            // hold the level, reset the slack clock.
            self.slack_since = None;
        }
        self.level
    }
}

/// Deadline-class-aware shed policy for a full queue: given the classes
/// of the queued tasks (`classes[..start]` are in service and
/// untouchable) and the class of the arriving task, pick the victim.
///
/// Returns `Some(index)` of the queued task to evict — the *youngest*
/// entry of the least-demanding class, so batch sheds first and the work
/// already closest to service survives — or `None` when the incoming task
/// itself is the cheapest to drop (its class is no more demanding than
/// everything queued).
pub fn shed_victim(
    classes: &[DeadlineClass],
    start: usize,
    incoming: DeadlineClass,
) -> Option<usize> {
    let mut victim: Option<(usize, f64)> = None;
    for (i, c) in classes.iter().enumerate().skip(start) {
        let w = c.weight();
        // `>=` keeps scanning: the youngest (back-most) minimal entry wins.
        if victim.is_none_or(|(_, bw)| bw >= w) {
            victim = Some((i, w));
        }
    }
    match victim {
        Some((i, w)) if w < incoming.weight() => Some(i),
        _ => None,
    }
}

/// The `[overload]` TOML block. `enabled == false` (no block present)
/// means the whole subsystem is inert: no bounded queues, no breaker, no
/// ladder, no new metric series — existing runs stay byte-identical.
#[derive(Clone, Debug)]
pub struct OverloadConfig {
    pub enabled: bool,
    /// Per-node queue capacity (tasks, including the one in service);
    /// 0 = unbounded.
    pub node_queue_cap: usize,
    /// Per-uplink queue capacity (transfers, including in flight);
    /// 0 = unbounded.
    pub uplink_queue_cap: usize,
    /// Max in-flight ack-timeout retries per home edge (0 = unlimited):
    /// caps PR 2's retry storm so a slow-node window cannot multiply
    /// queue depth.
    pub retry_budget: u32,
    pub breaker: BreakerConfig,
    pub ladder: LadderConfig,
    /// Fraction of detections dropped (deterministically, by task hash)
    /// while the ladder is at `Subsample` or above.
    pub subsample_drop: f64,
    /// Camera-burst windows: every detection in `[from, until)` yields
    /// `factor` tasks instead of one (the seeded overload scenario).
    pub bursts: Vec<BurstWindow>,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            enabled: false,
            node_queue_cap: 16,
            uplink_queue_cap: 8,
            retry_budget: 8,
            breaker: BreakerConfig::default(),
            ladder: LadderConfig::default(),
            subsample_drop: 0.5,
            bursts: Vec::new(),
        }
    }
}

impl OverloadConfig {
    /// Task multiplier at time `t` (1 outside every burst window;
    /// overlapping windows multiply).
    pub fn burst_factor(&self, t: f64) -> u32 {
        let mut f = 1u32;
        for b in &self.bursts {
            if b.covers(t) {
                f = f.saturating_mul(b.factor.max(1));
            }
        }
        f
    }

    /// Validate ranges (called by the config parser).
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.subsample_drop),
            "overload.subsample_drop must be in [0,1]"
        );
        anyhow::ensure!(
            self.ladder.up[0] <= self.ladder.up[1] && self.ladder.up[1] <= self.ladder.up[2],
            "overload.ladder_up must be non-decreasing"
        );
        anyhow::ensure!(self.breaker.cooldown > 0.0, "overload.cooldown must be positive");
        anyhow::ensure!(
            self.breaker.cooldown_max >= self.breaker.cooldown,
            "overload.cooldown_max must be >= overload.cooldown"
        );
        anyhow::ensure!(self.ladder.sustain > 0.0, "overload.ladder_sustain must be positive");
        for b in &self.bursts {
            anyhow::ensure!(b.until > b.from, "overload burst window must have until > from");
            anyhow::ensure!(b.factor >= 1, "overload burst_factor must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    fn bcfg() -> BreakerConfig {
        BreakerConfig { trip_after: 3, cooldown: 2.0, cooldown_max: 16.0, probe_successes: 2 }
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_only() {
        let mut b = CircuitBreaker::new(bcfg());
        assert_eq!(b.on_failure(1.0), None);
        assert_eq!(b.on_failure(1.1), None);
        // A success in between resets the streak.
        assert_eq!(b.on_success(1.2), None);
        assert_eq!(b.on_failure(1.3), None);
        assert_eq!(b.on_failure(1.4), None);
        assert_eq!(b.on_failure(1.5), Some(Transition::Opened));
        assert_eq!(b.state(), BreakerState::Open);
        // Open: traffic blocked until the dwell elapses.
        assert!(!b.allows(2.0).0);
        let (ok, tr) = b.allows(1.5 + 2.0);
        assert!(ok, "dwell elapsed: probe traffic allowed");
        assert_eq!(tr, Some(Transition::HalfOpened));
        assert_eq!(b.state(), BreakerState::HalfOpen);
    }

    #[test]
    fn breaker_half_open_closes_after_probe_successes() {
        let mut b = CircuitBreaker::new(bcfg());
        for i in 0..3 {
            b.on_failure(i as f64 * 0.1);
        }
        b.poll(10.0);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.on_success(10.1), None, "one probe is not enough");
        assert_eq!(b.on_success(10.2), Some(Transition::Closed));
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.dwell(), 2.0, "closing resets the dwell to the base cooldown");
    }

    #[test]
    fn breaker_failed_probe_reopens_with_doubled_dwell() {
        let mut b = CircuitBreaker::new(bcfg());
        for i in 0..3 {
            b.on_failure(i as f64 * 0.1);
        }
        b.poll(10.0);
        assert_eq!(b.on_failure(10.1), Some(Transition::Opened));
        assert_eq!(b.dwell(), 4.0, "failed probe doubles the dwell");
        assert!(!b.allows(12.0).0, "the longer dwell holds");
        assert!(b.allows(14.2).0);
        // Keep failing probes: the dwell saturates at cooldown_max.
        for _ in 0..8 {
            let t = b.opened_at + b.dwell;
            b.poll(t);
            b.on_failure(t + 0.01);
        }
        assert_eq!(b.dwell(), 16.0);
    }

    #[test]
    fn prop_breaker_transitions_stay_on_allowed_edges() {
        check("breaker_edges", |rng, _| {
            let cfg = BreakerConfig {
                trip_after: rng.range_usize(1, 5) as u32,
                cooldown: rng.range_f64(0.5, 4.0),
                cooldown_max: rng.range_f64(4.0, 32.0),
                probe_successes: rng.range_usize(1, 4) as u32,
            };
            let mut b = CircuitBreaker::new(cfg);
            let mut t = 0.0;
            for _ in 0..200 {
                t += rng.range_f64(0.01, 3.0);
                // Surface the dwell-elapse edge first: on_success/on_failure
                // poll internally, so without this a single call could take
                // the composite Open -> HalfOpen -> {Open, Closed} path and
                // look like an illegal edge from the outside.
                let pre = b.state();
                if let Some(tr) = b.poll(t) {
                    assert_eq!(pre, BreakerState::Open);
                    assert_eq!(tr, Transition::HalfOpened);
                    assert_eq!(b.state(), BreakerState::HalfOpen);
                }
                let before = b.state();
                let tr = match rng.range_usize(0, 3) {
                    0 => b.on_success(t),
                    1 => b.on_failure(t),
                    _ => b.poll(t),
                };
                let after = b.state();
                match (before, after) {
                    // Self-loops carry no transition event.
                    (a, b2) if a == b2 => assert_eq!(tr, None, "{a:?} self-loop emitted {tr:?}"),
                    (BreakerState::Closed, BreakerState::Open)
                    | (BreakerState::HalfOpen, BreakerState::Open) => {
                        assert_eq!(tr, Some(Transition::Opened))
                    }
                    (BreakerState::Open, BreakerState::HalfOpen) => {
                        assert_eq!(tr, Some(Transition::HalfOpened))
                    }
                    (BreakerState::HalfOpen, BreakerState::Closed) => {
                        assert_eq!(tr, Some(Transition::Closed))
                    }
                    (a, b2) => panic!("illegal transition {a:?} -> {b2:?}"),
                }
                // Invariant: dwell stays within [cooldown, max(cooldown, cooldown_max)].
                assert!(b.dwell() >= cfg.cooldown - 1e-12);
                assert!(b.dwell() <= cfg.cooldown_max.max(cfg.cooldown) + 1e-12);
            }
        });
    }

    #[test]
    fn prop_breaker_never_flaps_under_an_oscillating_oracle() {
        // An adversarial on/off fault oracle: while "down" every delivery
        // fails, while "up" every delivery succeeds, with a random
        // oscillation period. Hysteresis must make the gaps between
        // consecutive re-openings (with no close in between)
        // non-decreasing — the breaker probes a flapping link ever more
        // rarely instead of chattering.
        check("breaker_no_flap", |rng, _| {
            let cfg = bcfg();
            let mut b = CircuitBreaker::new(cfg);
            let period = rng.range_f64(0.3, 6.0);
            let dt = 0.05;
            let mut t = 0.0;
            // Openings since the last close: a close resets the dwell to
            // the base cooldown, so the monotone-gap claim only holds
            // within one open/probe/re-open run.
            let mut openings: Vec<f64> = Vec::new();
            let mut total_openings = 0usize;
            let mut gap_floor = 0.0f64;
            while t < 240.0 {
                t += dt;
                let down = ((t / period) as u64) % 2 == 0;
                let (ok, _) = b.allows(t);
                if !ok {
                    continue; // open: no traffic offered
                }
                let tr = if down { b.on_failure(t) } else { b.on_success(t) };
                match tr {
                    Some(Transition::Opened) => {
                        total_openings += 1;
                        if let Some(&prev) = openings.last() {
                            let gap = t - prev;
                            assert!(
                                gap + 1e-9 >= gap_floor.min(cfg.cooldown_max),
                                "re-opened after {gap:.2}s, floor was {gap_floor:.2}s"
                            );
                            gap_floor = gap_floor.max(gap.min(cfg.cooldown_max));
                        } else {
                            gap_floor = b.dwell();
                        }
                        openings.push(t);
                    }
                    Some(Transition::Closed) => {
                        gap_floor = 0.0;
                        openings.clear();
                    }
                    _ => {}
                }
            }
            // Hard bound: with a doubling dwell the breaker can open at
            // most ~ horizon/cooldown + log2(max/base) times; far below
            // the per-tick chatter an unhysteresised machine would show.
            assert!(
                total_openings as f64 <= 240.0 / cfg.cooldown + 8.0,
                "breaker flapped: {total_openings} openings"
            );
        });
    }

    #[test]
    fn prop_breaker_trajectory_is_seed_deterministic() {
        // The same event sequence must drive two breakers through the
        // same trajectory — no hidden state, no clock reads.
        check("breaker_determinism", |rng, _| {
            let cfg = BreakerConfig {
                trip_after: rng.range_usize(1, 5) as u32,
                cooldown: rng.range_f64(0.5, 4.0),
                cooldown_max: rng.range_f64(4.0, 32.0),
                probe_successes: rng.range_usize(1, 4) as u32,
            };
            let script: Vec<(f64, u8)> = {
                let mut t = 0.0;
                (0..128)
                    .map(|_| {
                        t += rng.range_f64(0.01, 2.0);
                        (t, rng.range_usize(0, 3) as u8)
                    })
                    .collect()
            };
            let mut a = CircuitBreaker::new(cfg);
            let mut b = CircuitBreaker::new(cfg);
            for &(t, op) in &script {
                let (ta, tb) = match op {
                    0 => (a.on_success(t), b.on_success(t)),
                    1 => (a.on_failure(t), b.on_failure(t)),
                    _ => (a.poll(t), b.poll(t)),
                };
                assert_eq!(ta, tb);
                assert_eq!(a.state(), b.state());
                assert_eq!(a.dwell(), b.dwell());
            }
        });
    }

    #[test]
    fn ladder_escalates_immediately_and_recovers_slowly() {
        let mut l = DegradationLadder::new(LadderConfig::default());
        assert_eq!(l.observe(0.2, 0.0), LoadLevel::Normal);
        assert_eq!(l.observe(0.6, 1.0), LoadLevel::Subsample);
        // Straight to Shed on a spike — no rung-at-a-time on the way up.
        assert_eq!(l.observe(0.95, 2.0), LoadLevel::Shed);
        // Slack must be *sustained*: a blip resets the clock.
        assert_eq!(l.observe(0.1, 3.0), LoadLevel::Shed);
        assert_eq!(l.observe(0.5, 5.0), LoadLevel::Shed, "pressure blip resets slack");
        assert_eq!(l.observe(0.1, 6.0), LoadLevel::Shed);
        assert_eq!(l.observe(0.1, 10.0), LoadLevel::Shed, "4s < sustain window");
        assert_eq!(l.observe(0.1, 11.0), LoadLevel::EdgeLocal, "one rung down after 5s slack");
        assert_eq!(l.observe(0.1, 16.0), LoadLevel::Subsample);
        assert_eq!(l.observe(0.1, 21.0), LoadLevel::Normal);
        assert_eq!(l.observe(0.1, 26.0), LoadLevel::Normal, "floor holds");
    }

    #[test]
    fn ladder_holds_level_between_slack_and_threshold() {
        let mut l = DegradationLadder::new(LadderConfig::default());
        l.observe(0.8, 0.0);
        assert_eq!(l.level(), LoadLevel::EdgeLocal);
        // 0.4 is below every up-threshold but above slack: hold.
        for i in 1..20 {
            assert_eq!(l.observe(0.4, i as f64), LoadLevel::EdgeLocal);
        }
    }

    #[test]
    fn shed_victim_sheds_batch_first_interactive_last() {
        use DeadlineClass::*;
        let q = [Interactive, Batch, Standard, Batch, Standard];
        // Youngest batch entry (index 3) goes first.
        assert_eq!(shed_victim(&q, 0, Interactive), Some(3));
        assert_eq!(shed_victim(&q, 0, Standard), Some(3));
        // An incoming batch task never evicts anyone of its own class.
        assert_eq!(shed_victim(&q, 0, Batch), None);
        // All-interactive queue: an incoming standard task sheds itself.
        assert_eq!(shed_victim(&[Interactive, Interactive], 0, Standard), None);
        // Interactive incoming evicts the youngest standard.
        assert_eq!(shed_victim(&[Standard, Interactive, Standard], 0, Interactive), Some(2));
        // The in-service prefix is untouchable.
        assert_eq!(shed_victim(&[Batch, Interactive], 1, Standard), None);
        assert_eq!(shed_victim(&[Batch, Batch, Interactive], 1, Standard), Some(1));
        // Empty scan range: shed the incoming task.
        assert_eq!(shed_victim(&[], 0, Batch), None);
    }

    #[test]
    fn prop_shed_victim_never_picks_a_more_demanding_class() {
        use DeadlineClass::*;
        let classes = [Interactive, Standard, Batch];
        check("shed_victim_order", |rng, _| {
            let n = rng.range_usize(0, 10);
            let q: Vec<DeadlineClass> =
                (0..n).map(|_| classes[rng.range_usize(0, 3)]).collect();
            let start = if n == 0 { 0 } else { rng.range_usize(0, n + 1) };
            let incoming = classes[rng.range_usize(0, 3)];
            match shed_victim(&q, start, incoming) {
                Some(i) => {
                    assert!(i >= start, "victim {i} inside the in-service prefix");
                    let vw = q[i].weight();
                    assert!(vw < incoming.weight(), "victim not cheaper than incoming");
                    for (j, c) in q.iter().enumerate().skip(start) {
                        assert!(
                            c.weight() >= vw,
                            "queued {j} ({c:?}) is cheaper than the victim"
                        );
                        if c.weight() == vw {
                            assert!(j <= i, "victim must be the youngest minimal entry");
                        }
                    }
                }
                None => {
                    // Correct iff nothing strictly cheaper is evictable.
                    assert!(q
                        .iter()
                        .skip(start)
                        .all(|c| c.weight() >= incoming.weight()));
                }
            }
        });
    }

    #[test]
    fn overload_config_defaults_disabled_and_validates() {
        let c = OverloadConfig::default();
        assert!(!c.enabled);
        assert!(c.validate().is_ok());
        assert_eq!(c.burst_factor(10.0), 1);
        let mut bad = OverloadConfig { subsample_drop: 1.5, ..OverloadConfig::default() };
        assert!(bad.validate().is_err());
        bad.subsample_drop = 0.5;
        bad.ladder.up = [0.9, 0.5, 0.7];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn burst_windows_multiply() {
        let c = OverloadConfig {
            bursts: vec![
                BurstWindow { from: 10.0, until: 20.0, factor: 3 },
                BurstWindow { from: 15.0, until: 30.0, factor: 2 },
            ],
            ..OverloadConfig::default()
        };
        assert_eq!(c.burst_factor(5.0), 1);
        assert_eq!(c.burst_factor(12.0), 3);
        assert_eq!(c.burst_factor(16.0), 6, "overlapping windows multiply");
        assert_eq!(c.burst_factor(25.0), 2);
        assert_eq!(c.burst_factor(30.0), 1, "half-open interval");
    }
}
