//! Moving-object detection (paper §IV-C).
//!
//! The paper runs OpenCV frame differencing on the edge CPU. Here the dense
//! stage (per-pixel diff → conjunction → grayscale → threshold → 3×3
//! dilation → 3×3 erosion) has two interchangeable implementations:
//!
//! * [`framediff::framediff_native`] — Rust, no dependencies (default).
//! * the `framediff` HLO artifact (Pallas kernel) executed via
//!   [`crate::runtime`] — benched against the native one in
//!   `bench_micro.rs` as a DESIGN.md §8 ablation.
//!
//! The irregular stage — contour extraction via Suzuki–Abe border following
//! ([`contour`]) and the paper's size/aspect filters — is always native.

pub mod contour;
pub mod framediff;

use crate::types::{BBox, Image};

/// Detection configuration (paper §IV-C parameters).
#[derive(Clone, Debug)]
pub struct DetectConfig {
    /// Fixed-level threshold on the grayscale conjunction (eq. 4), in
    /// [0,1] intensity units (paper uses 8-bit levels).
    pub threshold: f32,
    /// Discard boxes smaller than this many pixels on either side
    /// ("images with small sizes", §IV-C).
    pub min_side: usize,
    /// Discard boxes with max/min side ratio above this
    /// ("imbalances between length and width", §IV-C).
    pub max_aspect: f32,
    /// Margin added around each contour bbox before cropping.
    pub margin: usize,
    /// Crops are resized to this square resolution for the CNNs.
    pub crop_size: usize,
}

impl Default for DetectConfig {
    fn default() -> DetectConfig {
        DetectConfig { threshold: 0.1, min_side: 6, max_aspect: 3.0, margin: 2, crop_size: 32 }
    }
}

/// A detected foreground region.
#[derive(Clone, Debug)]
pub struct Detection {
    pub bbox: BBox,
    /// Number of mask pixels inside the bbox (component size).
    pub mass: usize,
}

/// Full detection pipeline over a frame triplet: dense stage → connected
/// regions → paper's plausibility filters. Returns boxes in frame coords.
pub fn detect(prev: &Image, cur: &Image, nxt: &Image, cfg: &DetectConfig) -> Vec<Detection> {
    let mask = framediff::framediff_native(prev, cur, nxt, cfg.threshold);
    detections_from_mask(&mask, cur.h, cur.w, cfg)
}

/// Shared tail of the pipeline (used by both the native and the HLO dense
/// stage): extract contours from a binary mask and filter boxes.
pub fn detections_from_mask(mask: &[u8], h: usize, w: usize, cfg: &DetectConfig) -> Vec<Detection> {
    contour::connected_regions(mask, h, w)
        .into_iter()
        .filter(|d| {
            d.bbox.height() >= cfg.min_side
                && d.bbox.width() >= cfg.min_side
                && d.bbox.aspect() <= cfg.max_aspect
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Image;

    fn moving_block_triplet(h: usize, w: usize) -> (Image, Image, Image) {
        let mut prev = Image::filled(h, w, [0.5, 0.5, 0.5]);
        let mut cur = prev.clone();
        let mut nxt = prev.clone();
        for y in 10..22 {
            for x in 4..16 {
                prev.set(y, x, [1.0, 1.0, 1.0]);
            }
            for x in 20..32 {
                cur.set(y, x, [1.0, 1.0, 1.0]);
            }
            for x in 36..48 {
                nxt.set(y, x, [1.0, 1.0, 1.0]);
            }
        }
        (prev, cur, nxt)
    }

    #[test]
    fn detects_moving_block() {
        let (prev, cur, nxt) = moving_block_triplet(48, 64);
        let dets = detect(&prev, &cur, &nxt, &DetectConfig::default());
        assert_eq!(dets.len(), 1, "expected exactly one detection: {dets:?}");
        let bb = dets[0].bbox;
        // The detection must overlap the block's *current* position.
        let want = BBox { y0: 10, x0: 20, y1: 22, x1: 32 };
        assert!(bb.iou(&want) > 0.4, "bbox {bb:?} vs want {want:?}");
    }

    #[test]
    fn static_scene_detects_nothing() {
        let img = Image::filled(48, 64, [0.3, 0.7, 0.2]);
        let dets = detect(&img, &img, &img, &DetectConfig::default());
        assert!(dets.is_empty());
    }

    #[test]
    fn small_detections_filtered() {
        let mut prev = Image::filled(32, 32, [0.5, 0.5, 0.5]);
        let mut cur = prev.clone();
        let mut nxt = prev.clone();
        // 2x2 flicker — below min_side after morphology.
        prev.set(5, 5, [1.0, 1.0, 1.0]);
        cur.set(5, 8, [1.0, 1.0, 1.0]);
        nxt.set(5, 11, [1.0, 1.0, 1.0]);
        let dets = detect(&prev, &cur, &nxt, &DetectConfig::default());
        assert!(dets.is_empty(), "single-pixel flicker should be filtered: {dets:?}");
    }

    #[test]
    fn aspect_filter_drops_slivers() {
        let cfg = DetectConfig::default();
        let mut mask = vec![0u8; 64 * 64];
        // A 40x4 sliver: aspect 10 > 3.
        for y in 10..50 {
            for x in 8..12 {
                mask[y * 64 + x] = 1;
            }
        }
        let dets = detections_from_mask(&mask, 64, 64, &cfg);
        assert!(dets.is_empty());
    }
}
