//! Dense stage of the frame-difference detector (paper eqs. 1–6), native
//! Rust implementation. Semantics match the Pallas kernel
//! (`python/compile/kernels/framediff.py`) exactly; an integration test
//! compares this path against the HLO artifact on the same triplets.

use crate::types::Image;

/// Per-element absolute differences (eqs. 1–2), conjunction as elementwise
/// min (eq. 3), grayscale by channel mean, fixed-level threshold (eq. 4),
/// then 3×3 dilation (eq. 5) and 3×3 erosion (eq. 6). Returns a binary
/// mask (0/1) of size `h*w`.
pub fn framediff_native(prev: &Image, cur: &Image, nxt: &Image, threshold: f32) -> Vec<u8> {
    assert_eq!(prev.data.len(), cur.data.len());
    assert_eq!(nxt.data.len(), cur.data.len());
    let (h, w) = (cur.h, cur.w);
    let mut binary = vec![0u8; h * w];
    for i in 0..h * w {
        let o = i * 3;
        let mut gray = 0.0f32;
        for ch in 0..3 {
            let d1 = (cur.data[o + ch] - prev.data[o + ch]).abs();
            let d2 = (nxt.data[o + ch] - cur.data[o + ch]).abs();
            gray += d1.min(d2);
        }
        gray /= 3.0;
        binary[i] = (gray > threshold) as u8;
    }
    let dilated = morph3x3(&binary, h, w, true);
    morph3x3(&dilated, h, w, false)
}

/// 3×3 max (dilate) / min (erode) filter with neutral-value border
/// (0 for dilation, 1 for erosion) — same convention as the kernel.
///
/// Separable implementation (§Perf): a 3×3 max/min equals a 1×3 pass
/// followed by a 3×1 pass — 6 reads per pixel instead of 9, sequential
/// row-major access in both passes (≈2.4x faster than the naive window
/// on this host; see EXPERIMENTS.md §Perf).
pub fn morph3x3(mask: &[u8], h: usize, w: usize, dilate: bool) -> Vec<u8> {
    let neutral = if dilate { 0u8 } else { 1u8 };
    let pick = |a: u8, b: u8| if dilate { a.max(b) } else { a.min(b) };
    // Horizontal pass.
    let mut hpass = vec![neutral; h * w];
    for y in 0..h {
        let row = &mask[y * w..(y + 1) * w];
        let out = &mut hpass[y * w..(y + 1) * w];
        if w == 1 {
            out[0] = row[0];
            continue;
        }
        out[0] = pick(row[0], row[1]);
        for x in 1..w - 1 {
            out[x] = pick(pick(row[x - 1], row[x]), row[x + 1]);
        }
        out[w - 1] = pick(row[w - 2], row[w - 1]);
    }
    // Vertical pass (row-major: combine three source rows per output row).
    let mut out = vec![neutral; h * w];
    for y in 0..h {
        let dst = y * w;
        let mid = &hpass[y * w..(y + 1) * w];
        match (y > 0, y + 1 < h) {
            (true, true) => {
                let up = &hpass[(y - 1) * w..y * w];
                let dn = &hpass[(y + 1) * w..(y + 2) * w];
                for x in 0..w {
                    out[dst + x] = pick(pick(up[x], mid[x]), dn[x]);
                }
            }
            (true, false) => {
                let up = &hpass[(y - 1) * w..y * w];
                for x in 0..w {
                    out[dst + x] = pick(up[x], mid[x]);
                }
            }
            (false, true) => {
                let dn = &hpass[(y + 1) * w..(y + 2) * w];
                for x in 0..w {
                    out[dst + x] = pick(mid[x], dn[x]);
                }
            }
            (false, false) => out[dst..dst + w].copy_from_slice(mid),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    fn rand_image(rng: &mut Rng, h: usize, w: usize) -> Image {
        let mut img = Image::new(h, w);
        for v in img.data.iter_mut() {
            *v = rng.f32();
        }
        img
    }

    #[test]
    fn identical_frames_empty_mask() {
        let mut rng = Rng::new(1);
        let img = rand_image(&mut rng, 20, 24);
        let mask = framediff_native(&img, &img, &img, 0.05);
        assert!(mask.iter().all(|&m| m == 0));
    }

    #[test]
    fn conjunction_requires_motion_in_both_diffs() {
        // Change only between prev and cur (object appears then stays):
        // d2 = 0 everywhere, so min(d1, d2) = 0 => nothing detected.
        let base = Image::filled(16, 16, [0.2, 0.2, 0.2]);
        let mut changed = base.clone();
        for y in 4..12 {
            for x in 4..12 {
                changed.set(y, x, [0.9, 0.9, 0.9]);
            }
        }
        let mask = framediff_native(&base, &changed, &changed, 0.1);
        assert!(mask.iter().all(|&m| m == 0), "appear-and-stay must not fire");
    }

    #[test]
    fn dilate_then_erode_fills_small_holes() {
        let (h, w) = (12, 12);
        let mut mask = vec![0u8; h * w];
        // Ring with a one-pixel hole in the middle.
        for y in 3..9 {
            for x in 3..9 {
                mask[y * w + x] = 1;
            }
        }
        mask[6 * w + 6] = 0;
        let closed = morph3x3(&morph3x3(&mask, h, w, true), h, w, false);
        assert_eq!(closed[6 * w + 6], 1, "closing must fill the hole");
    }

    #[test]
    fn erosion_removes_isolated_pixels() {
        let (h, w) = (10, 10);
        let mut mask = vec![0u8; h * w];
        mask[5 * w + 5] = 1;
        let eroded = morph3x3(&mask, h, w, false);
        assert!(eroded.iter().all(|&m| m == 0));
    }

    #[test]
    fn dilation_grows_by_one() {
        let (h, w) = (10, 10);
        let mut mask = vec![0u8; h * w];
        mask[5 * w + 5] = 1;
        let dilated = morph3x3(&mask, h, w, true);
        let count: usize = dilated.iter().map(|&m| m as usize).sum();
        assert_eq!(count, 9);
    }

    #[test]
    fn prop_mask_is_binary_and_deterministic() {
        check("framediff_binary_deterministic", |rng, _| {
            let h = rng.range_usize(4, 24);
            let w = rng.range_usize(4, 24);
            let a = rand_image(rng, h, w);
            let b = rand_image(rng, h, w);
            let c = rand_image(rng, h, w);
            let thr = rng.range_f32(0.02, 0.5);
            let m1 = framediff_native(&a, &b, &c, thr);
            let m2 = framediff_native(&a, &b, &c, thr);
            assert_eq!(m1, m2);
            assert!(m1.iter().all(|&v| v <= 1));
        });
    }

    #[test]
    fn prop_threshold_monotone() {
        // Raising the threshold can only shrink the pre-morphology mask;
        // after closing, total mass must be non-increasing too.
        check("framediff_threshold_monotone", |rng, _| {
            let a = rand_image(rng, 16, 16);
            let b = rand_image(rng, 16, 16);
            let c = rand_image(rng, 16, 16);
            let t1 = rng.range_f32(0.02, 0.3);
            let t2 = t1 + rng.range_f32(0.05, 0.3);
            let m1: usize = framediff_native(&a, &b, &c, t1).iter().map(|&v| v as usize).sum();
            let m2: usize = framediff_native(&a, &b, &c, t2).iter().map(|&v| v as usize).sum();
            assert!(m2 <= m1, "mass grew when threshold rose: {m1} -> {m2}");
        });
    }
}
