//! Connected foreground regions from a binary mask.
//!
//! The paper retrieves object contours with the Suzuki–Abe border-following
//! algorithm (its ref. [24], the one behind OpenCV `findContours`). For the
//! pipeline we need each region's bounding box and mass, so we implement
//! border following to trace each outer contour, then derive the bbox from
//! the traced border. A flood-fill labelling pass guarantees each component
//! is reported exactly once (border following alone can revisit components
//! with complex topology).

use super::Detection;
use crate::types::BBox;

/// Moore-neighbourhood offsets, clockwise starting east.
const NBR8: [(i64, i64); 8] =
    [(0, 1), (1, 1), (1, 0), (1, -1), (0, -1), (-1, -1), (-1, 0), (-1, 1)];

/// Trace the outer border of the component containing `(sy, sx)` (which
/// must be a foreground pixel whose west neighbour is background), marking
/// border pixels in `visited`. Returns the border pixel list.
pub fn trace_border(mask: &[u8], h: usize, w: usize, sy: usize, sx: usize) -> Vec<(usize, usize)> {
    let at = |y: i64, x: i64| -> u8 {
        if y < 0 || y >= h as i64 || x < 0 || x >= w as i64 {
            0
        } else {
            mask[y as usize * w + x as usize]
        }
    };
    let mut border = vec![(sy, sx)];
    // Previous direction: we entered from the west.
    let (mut cy, mut cx) = (sy as i64, sx as i64);
    let mut prev_dir = 4usize; // pointing west (where we came from)
    loop {
        // Search clockwise from the pixel after the backtrack direction.
        let mut found = None;
        for k in 1..=8 {
            let dir = (prev_dir + k) % 8;
            let (dy, dx) = NBR8[dir];
            if at(cy + dy, cx + dx) != 0 {
                found = Some(dir);
                break;
            }
        }
        let Some(dir) = found else {
            break; // isolated pixel
        };
        let (dy, dx) = NBR8[dir];
        cy += dy;
        cx += dx;
        if (cy as usize, cx as usize) == (sy, sx) && border.len() > 1 {
            break;
        }
        border.push((cy as usize, cx as usize));
        // Backtrack direction = opposite of the move we just made.
        prev_dir = (dir + 4) % 8;
        if border.len() > 4 * h * w {
            break; // safety bound; cannot trigger on valid input
        }
    }
    border
}

/// All connected components (8-connectivity) of the mask as [`Detection`]s:
/// bbox from the traced outer border, mass from the filled component.
pub fn connected_regions(mask: &[u8], h: usize, w: usize) -> Vec<Detection> {
    let mut labels = vec![0u32; h * w];
    let mut next_label = 1u32;
    let mut out = Vec::new();
    let mut stack = Vec::new();
    for y in 0..h {
        for x in 0..w {
            let i = y * w + x;
            if mask[i] == 0 || labels[i] != 0 {
                continue;
            }
            // New component: flood-fill for mass + extent ...
            let label = next_label;
            next_label += 1;
            labels[i] = label;
            stack.push((y, x));
            let (mut y0, mut x0, mut y1, mut x1) = (y, x, y, x);
            let mut mass = 0usize;
            while let Some((py, px)) = stack.pop() {
                mass += 1;
                y0 = y0.min(py);
                x0 = x0.min(px);
                y1 = y1.max(py);
                x1 = x1.max(px);
                for (dy, dx) in NBR8 {
                    let ny = py as i64 + dy;
                    let nx = px as i64 + dx;
                    if ny < 0 || ny >= h as i64 || nx < 0 || nx >= w as i64 {
                        continue;
                    }
                    let ni = ny as usize * w + nx as usize;
                    if mask[ni] != 0 && labels[ni] == 0 {
                        labels[ni] = label;
                        stack.push((ny as usize, nx as usize));
                    }
                }
            }
            // ... and trace the outer border from the first (top-left)
            // pixel, Suzuki-style. The border is a sanity witness: every
            // traced pixel must lie inside the filled extent (a single
            // clockwise pass can legally skip thin appendages, so the fill
            // extent — not the trace extent — is the bbox of record).
            let border = trace_border(mask, h, w, y, x);
            debug_assert!(border
                .iter()
                .all(|&(py, px)| py >= y0 && py <= y1 && px >= x0 && px <= x1));
            out.push(Detection {
                bbox: BBox { y0, x0, y1: y1 + 1, x1: x1 + 1 },
                mass,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    fn mask_with_rect(h: usize, w: usize, y0: usize, x0: usize, y1: usize, x1: usize) -> Vec<u8> {
        let mut m = vec![0u8; h * w];
        for y in y0..y1 {
            for x in x0..x1 {
                m[y * w + x] = 1;
            }
        }
        m
    }

    #[test]
    fn single_rect() {
        let m = mask_with_rect(20, 20, 3, 4, 9, 12);
        let regions = connected_regions(&m, 20, 20);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].bbox, BBox { y0: 3, x0: 4, y1: 9, x1: 12 });
        assert_eq!(regions[0].mass, 6 * 8);
    }

    #[test]
    fn two_separate_rects() {
        let mut m = mask_with_rect(20, 30, 2, 2, 6, 6);
        for y in 10..15 {
            for x in 20..28 {
                m[y * 30 + x] = 1;
            }
        }
        let mut regions = connected_regions(&m, 20, 30);
        regions.sort_by_key(|r| r.bbox.y0);
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].bbox, BBox { y0: 2, x0: 2, y1: 6, x1: 6 });
        assert_eq!(regions[1].bbox, BBox { y0: 10, x0: 20, y1: 15, x1: 28 });
    }

    #[test]
    fn diagonal_pixels_are_one_component() {
        // 8-connectivity: a diagonal line is a single region.
        let mut m = vec![0u8; 10 * 10];
        for i in 0..6 {
            m[(2 + i) * 10 + (3 + i)] = 1;
        }
        let regions = connected_regions(&m, 10, 10);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].mass, 6);
    }

    #[test]
    fn l_shape_bbox() {
        let mut m = vec![0u8; 12 * 12];
        for y in 2..10 {
            m[y * 12 + 2] = 1;
        }
        for x in 2..9 {
            m[9 * 12 + x] = 1;
        }
        let regions = connected_regions(&m, 12, 12);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].bbox, BBox { y0: 2, x0: 2, y1: 10, x1: 9 });
    }

    #[test]
    fn region_with_hole_traced_once() {
        // Hollow square: one component, mass = ring only.
        let mut m = mask_with_rect(16, 16, 3, 3, 12, 12);
        for y in 6..9 {
            for x in 6..9 {
                m[y * 16 + x] = 0;
            }
        }
        let regions = connected_regions(&m, 16, 16);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].mass, 9 * 9 - 9);
    }

    #[test]
    fn isolated_pixel() {
        let mut m = vec![0u8; 8 * 8];
        m[3 * 8 + 4] = 1;
        let regions = connected_regions(&m, 8, 8);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].mass, 1);
        assert_eq!(regions[0].bbox, BBox { y0: 3, x0: 4, y1: 4, x1: 5 });
    }

    #[test]
    fn border_trace_touching_edges() {
        // Component touching all four image borders must not panic.
        let m = mask_with_rect(6, 6, 0, 0, 6, 6);
        let regions = connected_regions(&m, 6, 6);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].bbox, BBox { y0: 0, x0: 0, y1: 6, x1: 6 });
    }

    #[test]
    fn prop_masses_sum_to_foreground() {
        check("component_masses_sum", |rng, _| {
            let h = rng.range_usize(4, 32);
            let w = rng.range_usize(4, 32);
            let mut m = vec![0u8; h * w];
            for v in m.iter_mut() {
                *v = rng.bool(0.3) as u8;
            }
            let regions = connected_regions(&m, h, w);
            let total: usize = regions.iter().map(|r| r.mass).sum();
            let fg: usize = m.iter().map(|&v| v as usize).sum();
            assert_eq!(total, fg);
        });
    }

    #[test]
    fn prop_bboxes_contain_their_mass() {
        check("component_bbox_bounds", |rng, _| {
            let h = rng.range_usize(4, 24);
            let w = rng.range_usize(4, 24);
            let mut m = vec![0u8; h * w];
            for v in m.iter_mut() {
                *v = rng.bool(0.25) as u8;
            }
            for r in connected_regions(&m, h, w) {
                assert!(r.bbox.y1 <= h && r.bbox.x1 <= w);
                assert!(r.mass <= r.bbox.area());
                assert!(r.mass >= 1);
            }
        });
    }
}
