//! Observability: metric registry, per-task stage spans, and exporters.
//!
//! The paper's headline claims (§V: 5.4× faster query response, 7× less
//! bandwidth) are *measurements*, so the pipeline exposes first-class
//! metrics instead of opaque end-to-end aggregates:
//!
//! * [`Registry`] — counters, gauges and log-bucketed [`Histogram`]s,
//!   keyed by metric name + sorted label set. Cheap to clone (all clones
//!   share state), `Send + Sync`.
//! * [`SpanEvent`] — one per-task pipeline [`Stage`] (detect →
//!   edge-infer → threshold-decide → queue → uplink → cloud-infer →
//!   verdict) or fault event (retry / reroute / degrade), stamped with
//!   simulated time, so an export reconstructs every task's timeline.
//! * Exporters — [`Registry::export_jsonl`] (structured event log, one
//!   JSON object per line, parseable by `runtime::json`) and
//!   [`Registry::export_prometheus`] (text exposition). Both are
//!   deterministic: same-seed runs produce byte-identical exports
//!   (BTreeMap series order, insertion-ordered events, no wall-clock).
//!
//! Metric naming scheme (DESIGN.md §9): `surveiledge_<subsystem>_<what>`,
//! lowercase `[a-z0-9_]`, `_total` suffix on counters, `_seconds` /
//! `_bytes` unit suffixes. [`validate_prometheus`] and [`validate_jsonl`]
//! enforce the rules (CI `observability` job, `surveiledge obs-check`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::runtime::json::Json;

/// A pipeline stage or fault event on a task's timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Frame-difference detection produced the crop.
    Detect,
    /// Edge CQ-CNN classification.
    EdgeInfer,
    /// α/β band decision on the edge confidence.
    ThresholdDecide,
    /// Waiting in a node's FIFO before service.
    Queue,
    /// Crop transfer on the home edge's uplink (queue + wire time).
    Uplink,
    /// Cloud high-accuracy CNN classification.
    CloudInfer,
    /// Final answer recorded (dur = end-to-end latency).
    Verdict,
    /// Delivery failed; the task backs off and re-dispatches.
    Retry,
    /// Failover sweep re-allocated the task off a dead node.
    Reroute,
    /// Answered edge-locally because the cloud path was unavailable.
    Degrade,
    /// A query passed admission control and joined the registry.
    QueryAdmit,
    /// A query was retired from the registry.
    QueryRetire,
    /// Overload control explicitly dropped the task (bounded-queue
    /// overflow, ladder shed level, or exhausted retry budget).
    Shed,
    /// The degradation ladder dropped the detection before it became a
    /// task (frame subsampling).
    Subsample,
    /// An uplink circuit breaker tripped open.
    CircuitOpen,
    /// An open breaker half-opened to probe the uplink.
    CircuitProbe,
    /// A half-open breaker closed after successful probes.
    CircuitClose,
}

impl Stage {
    /// The seven pipeline stages, in flow order.
    pub const PIPELINE: [Stage; 7] = [
        Stage::Detect,
        Stage::EdgeInfer,
        Stage::ThresholdDecide,
        Stage::Queue,
        Stage::Uplink,
        Stage::CloudInfer,
        Stage::Verdict,
    ];

    /// The fault/recovery events.
    pub const FAULT_EVENTS: [Stage; 3] = [Stage::Retry, Stage::Reroute, Stage::Degrade];

    /// Query lifecycle events (emitted by `query::QueryRegistry`).
    pub const QUERY_EVENTS: [Stage; 2] = [Stage::QueryAdmit, Stage::QueryRetire];

    /// Overload-control events (emitted only when `[overload]` is
    /// configured; see `crate::overload`).
    pub const OVERLOAD_EVENTS: [Stage; 5] = [
        Stage::Shed,
        Stage::Subsample,
        Stage::CircuitOpen,
        Stage::CircuitProbe,
        Stage::CircuitClose,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Detect => "detect",
            Stage::EdgeInfer => "edge_infer",
            Stage::ThresholdDecide => "threshold_decide",
            Stage::Queue => "queue",
            Stage::Uplink => "uplink",
            Stage::CloudInfer => "cloud_infer",
            Stage::Verdict => "verdict",
            Stage::Retry => "retry",
            Stage::Reroute => "reroute",
            Stage::Degrade => "degrade",
            Stage::QueryAdmit => "query_admit",
            Stage::QueryRetire => "query_retire",
            Stage::Shed => "shed",
            Stage::Subsample => "subsample",
            Stage::CircuitOpen => "circuit_open",
            Stage::CircuitProbe => "circuit_probe",
            Stage::CircuitClose => "circuit_close",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::PIPELINE
            .into_iter()
            .chain(Stage::FAULT_EVENTS)
            .chain(Stage::QUERY_EVENTS)
            .chain(Stage::OVERLOAD_EVENTS)
            .find(|stage| stage.as_str() == s)
    }

    /// Is this a recovery event rather than a pipeline stage?
    pub fn is_fault_event(self) -> bool {
        Stage::FAULT_EVENTS.contains(&self)
    }
}

/// One entry on a task's stage timeline.
///
/// `dur` is the stage's duration in simulated seconds (`0` for point
/// events like the band decision or a retry); `t` is when the stage
/// *ended*, so the stage spans `[t - dur, t]`.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    pub t: f64,
    pub task: u64,
    pub stage: Stage,
    /// The node involved (0 = cloud, `k` = edge `k`).
    pub node: u32,
    pub dur: f64,
    pub scheme: String,
    /// Free-form annotation (band decision, verdict site, ...).
    pub detail: String,
}

/// Label for node ids in metric series (`cloud`, `edge1`, ...).
pub fn node_label(node: u32) -> String {
    if node == 0 {
        "cloud".to_string()
    } else {
        format!("edge{node}")
    }
}

/// A log-bucketed histogram with Prometheus-style cumulative export.
///
/// Bucket `i` counts observations in `(bounds[i-1], bounds[i]]`; one
/// overflow bucket past the last bound (`+Inf`). Merging requires
/// identical bounds and is associative (property-tested).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` entries; the last is the overflow (+Inf) bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    pub fn new(bounds: Vec<f64>) -> Histogram {
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], sum: 0.0, count: 0 }
    }

    /// Geometric bounds `lo, lo·factor, lo·factor², ...` (n bounds).
    pub fn log_bucketed(lo: f64, factor: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && factor > 1.0 && n > 0, "log_bucketed(lo>0, factor>1, n>0)");
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram::new(bounds)
    }

    /// Default latency buckets: 1 ms .. ~2.3 h in ×2 steps (24 bounds).
    pub fn default_latency() -> Histogram {
        Histogram::log_bucketed(1e-3, 2.0, 24)
    }

    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Merge another histogram's counts in (same bounds required).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram merge requires identical bounds");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts, overflow bucket last.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

type Labels = Vec<(String, String)>;
type SeriesKey = (String, Labels);

#[derive(Default)]
struct Inner {
    counters: BTreeMap<SeriesKey, u64>,
    gauges: BTreeMap<SeriesKey, f64>,
    histograms: BTreeMap<SeriesKey, Histogram>,
    events: Vec<SpanEvent>,
}

/// The metric registry. Cheap to clone; all clones share state.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<Inner>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> SeriesKey {
        let mut l: Labels =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        l.sort();
        (name.to_string(), l)
    }

    /// Increment a counter series by `by` (creates it at 0 first).
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], by: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(Self::key(name, labels)).or_insert(0) += by;
    }

    /// Set a gauge series to `v`.
    pub fn gauge_set(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(Self::key(name, labels), v);
    }

    /// Observe `v` into a histogram series (created with the default
    /// latency buckets on first touch).
    pub fn observe(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner
            .histograms
            .entry(Self::key(name, labels))
            .or_insert_with(Histogram::default_latency)
            .observe(v);
    }

    /// Append a span event to the timeline.
    pub fn span(&self, ev: SpanEvent) {
        self.inner.lock().unwrap().events.push(ev);
    }

    /// Current value of a counter series (0 if absent).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.inner.lock().unwrap().counters.get(&Self::key(name, labels)).copied().unwrap_or(0)
    }

    /// Current value of a gauge series.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(&Self::key(name, labels)).copied()
    }

    /// Snapshot of a histogram series.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<Histogram> {
        self.inner.lock().unwrap().histograms.get(&Self::key(name, labels)).cloned()
    }

    /// Snapshot of the event timeline (insertion order).
    pub fn events(&self) -> Vec<SpanEvent> {
        self.inner.lock().unwrap().events.clone()
    }

    pub fn event_count(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    /// Fold another registry's state into this one: counters add, gauges
    /// overwrite (last merge wins), histograms merge bucket-wise, events
    /// append in `other`'s recording order. `run_all_schemes` gives each
    /// concurrent scheme run a private child registry and merges the
    /// children back in spec order, which makes a shared registry's
    /// exports byte-identical to a sequential run.
    pub fn merge_from(&self, other: &Registry) {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return; // same underlying state: nothing to fold
        }
        let src = other.inner.lock().unwrap();
        let mut dst = self.inner.lock().unwrap();
        for (k, v) in &src.counters {
            *dst.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &src.gauges {
            dst.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &src.histograms {
            match dst.histograms.get_mut(k) {
                Some(existing) => existing.merge(h),
                None => {
                    dst.histograms.insert(k.clone(), h.clone());
                }
            }
        }
        dst.events.extend(src.events.iter().cloned());
    }

    /// Structured JSONL event log: one JSON object per span event, in
    /// recording order. Deterministic for a deterministic run.
    pub fn export_jsonl(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for e in &inner.events {
            let _ = writeln!(
                out,
                "{{\"t\":{},\"task\":{},\"stage\":\"{}\",\"node\":{},\"dur\":{},\"scheme\":\"{}\",\"detail\":\"{}\"}}",
                fmt_num(e.t),
                e.task,
                e.stage.as_str(),
                e.node,
                fmt_num(e.dur),
                escape(&e.scheme),
                escape(&e.detail),
            );
        }
        out
    }

    /// Prometheus text exposition: counters, then gauges, then histograms,
    /// each section in (name, labels) order with one `# TYPE` line per
    /// metric name. Deterministic byte-for-byte.
    pub fn export_prometheus(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        let mut last: Option<&str> = None;
        for ((name, labels), v) in &inner.counters {
            if last != Some(name.as_str()) {
                let _ = writeln!(out, "# TYPE {name} counter");
                last = Some(name);
            }
            let _ = writeln!(out, "{}{} {}", name, fmt_labels(labels), v);
        }
        last = None;
        for ((name, labels), v) in &inner.gauges {
            if last != Some(name.as_str()) {
                let _ = writeln!(out, "# TYPE {name} gauge");
                last = Some(name);
            }
            let _ = writeln!(out, "{}{} {}", name, fmt_labels(labels), fmt_num(*v));
        }
        last = None;
        for ((name, labels), h) in &inner.histograms {
            if last != Some(name.as_str()) {
                let _ = writeln!(out, "# TYPE {name} histogram");
                last = Some(name);
            }
            let mut cum = 0u64;
            for (bound, c) in h.bounds.iter().zip(&h.counts) {
                cum += c;
                let _ = writeln!(
                    out,
                    "{}_bucket{} {}",
                    name,
                    fmt_labels_le(labels, &fmt_num(*bound)),
                    cum
                );
            }
            let _ = writeln!(out, "{}_bucket{} {}", name, fmt_labels_le(labels, "+Inf"), h.count);
            let _ = writeln!(out, "{}_sum{} {}", name, fmt_labels(labels), fmt_num(h.sum));
            let _ = writeln!(out, "{}_count{} {}", name, fmt_labels(labels), h.count);
        }
        out
    }

    /// Write `events.jsonl` + `metrics.prom` into `dir` (created if
    /// missing).
    pub fn write_exports(&self, dir: &Path) -> crate::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("events.jsonl"), self.export_jsonl())?;
        std::fs::write(dir.join("metrics.prom"), self.export_prometheus())?;
        Ok(())
    }
}

/// The full `--obs-out DIR` export: `events.jsonl`, `metrics.prom`, and
/// `report.json`. Creates `dir` (and any missing parents) first, so a
/// fresh output path never errors — every binary subcommand goes
/// through here.
pub fn write_obs_dir(dir: &Path, reg: &Registry, reports: &[Report]) -> crate::Result<()> {
    std::fs::create_dir_all(dir)?;
    reg.write_exports(dir)?;
    std::fs::write(dir.join("report.json"), reports_to_json(reports))?;
    Ok(())
}

/// Deterministic number formatting shared by both exporters: Rust's
/// shortest-roundtrip `Display` (never exponent notation for f64), with
/// non-finite values clamped to 0 so the JSON stays parseable.
fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}=\"{}\"", k, escape(v));
    }
    out.push('}');
    out
}

/// Labels plus the histogram `le` bound appended last.
fn fmt_labels_le(labels: &[(String, String)], le: &str) -> String {
    let mut out = String::from("{");
    for (k, v) in labels {
        let _ = write!(out, "{}=\"{}\",", k, escape(v));
    }
    let _ = write!(out, "le=\"{le}\"");
    out.push('}');
    out
}

fn is_metric_name(s: &str) -> bool {
    !s.is_empty()
        && !s.starts_with(|c: char| c.is_ascii_digit())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Parse one Prometheus sample line into a canonical series string and
/// its value text. Returns `None` on any syntax violation.
fn parse_series_line(line: &str) -> Option<(String, String, String)> {
    let chars: Vec<char> = line.chars().collect();
    let name_ok = |c: char| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_';
    let mut i = 0usize;
    let mut name = String::new();
    while i < chars.len() && name_ok(chars[i]) {
        name.push(chars[i]);
        i += 1;
    }
    if !is_metric_name(&name) {
        return None;
    }
    let mut series = name.clone();
    if i < chars.len() && chars[i] == '{' {
        series.push('{');
        i += 1;
        loop {
            if i < chars.len() && chars[i] == '}' {
                series.push('}');
                i += 1;
                break;
            }
            let mut lname = String::new();
            while i < chars.len() && name_ok(chars[i]) {
                lname.push(chars[i]);
                i += 1;
            }
            if !is_metric_name(&lname) {
                return None;
            }
            if i >= chars.len() || chars[i] != '=' {
                return None;
            }
            i += 1;
            if i >= chars.len() || chars[i] != '"' {
                return None;
            }
            i += 1;
            let mut lval = String::new();
            while i < chars.len() && chars[i] != '"' {
                if chars[i] == '\\' {
                    lval.push(chars[i]);
                    i += 1;
                    if i >= chars.len() {
                        return None;
                    }
                }
                lval.push(chars[i]);
                i += 1;
            }
            if i >= chars.len() {
                return None; // unterminated label value
            }
            i += 1; // closing quote
            let _ = write!(series, "{lname}=\"{lval}\"");
            if i < chars.len() && chars[i] == ',' {
                series.push(',');
                i += 1;
            }
        }
    }
    if i >= chars.len() || chars[i] != ' ' {
        return None;
    }
    i += 1;
    let value: String = chars[i..].iter().collect();
    if value.is_empty() || value.contains(' ') {
        return None;
    }
    Some((name, series, value))
}

/// Validate a Prometheus text exposition: naming rules
/// (`[a-z_][a-z0-9_]*`), well-formed `# TYPE` lines, every sample
/// declared by a TYPE, numeric values, and **no duplicate series**.
pub fn validate_prometheus(text: &str) -> crate::Result<()> {
    use std::collections::HashSet;
    let mut typed: HashSet<String> = HashSet::new();
    let mut seen: HashSet<String> = HashSet::new();
    for (ln, line) in text.lines().enumerate() {
        let n = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let parts: Vec<&str> = rest.split(' ').collect();
            if parts.len() != 2 {
                anyhow::bail!("metrics.prom line {n}: malformed TYPE line");
            }
            let (tname, kind) = (parts[0], parts[1]);
            if !is_metric_name(tname) {
                anyhow::bail!("metrics.prom line {n}: bad metric name {tname:?}");
            }
            if !matches!(kind, "counter" | "gauge" | "histogram") {
                anyhow::bail!("metrics.prom line {n}: unknown metric type {kind:?}");
            }
            if !typed.insert(tname.to_string()) {
                anyhow::bail!("metrics.prom line {n}: duplicate TYPE for {tname}");
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // other comments are legal
        }
        let Some((name, series, value)) = parse_series_line(line) else {
            anyhow::bail!("metrics.prom line {n}: malformed sample line {line:?}");
        };
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .unwrap_or(&name);
        if !typed.contains(&name) && !typed.contains(base) {
            anyhow::bail!("metrics.prom line {n}: sample {name} has no TYPE declaration");
        }
        if value.parse::<f64>().is_err() {
            anyhow::bail!("metrics.prom line {n}: non-numeric value {value:?}");
        }
        if !seen.insert(series.clone()) {
            anyhow::bail!("metrics.prom line {n}: duplicate series {series}");
        }
    }
    Ok(())
}

/// Validate a JSONL event log: every line parses through
/// [`crate::runtime::json`] and carries the span schema (t, task, stage,
/// node, dur, scheme) with a known stage name. Returns the event count.
pub fn validate_jsonl(text: &str) -> crate::Result<usize> {
    let mut n = 0usize;
    for (ln, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("events.jsonl line {}: {e}", ln + 1))?;
        for key in ["t", "task", "stage", "node", "dur", "scheme"] {
            if j.get(key).is_none() {
                anyhow::bail!("events.jsonl line {}: missing key {key:?}", ln + 1);
            }
        }
        let stage = j
            .get("stage")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("events.jsonl line {}: stage is not a string", ln + 1))?;
        if Stage::parse(stage).is_none() {
            anyhow::bail!("events.jsonl line {}: unknown stage {stage:?}", ln + 1);
        }
        n += 1;
    }
    Ok(n)
}

/// The one result type every consumer reads metrics through: a kind tag
/// (`scheme_run`, `micro_bench`), a name, and a flat ordered metric map.
/// JSON schema (stable): `{"kind":..., "name":..., "metrics":{...}}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    pub kind: String,
    pub name: String,
    metrics: Vec<(String, f64)>,
}

impl Report {
    pub fn new(kind: &str, name: &str) -> Report {
        Report { kind: kind.to_string(), name: name.to_string(), metrics: Vec::new() }
    }

    /// Set a metric (replaces an existing key, preserves first-insert
    /// order otherwise).
    pub fn push(&mut self, key: &str, v: f64) {
        if let Some(slot) = self.metrics.iter_mut().find(|(k, _)| k == key) {
            slot.1 = v;
        } else {
            self.metrics.push((key.to_string(), v));
        }
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    pub fn metrics(&self) -> &[(String, f64)] {
        &self.metrics
    }

    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"kind\":\"{}\",\"name\":\"{}\",\"metrics\":{{",
            escape(&self.kind),
            escape(&self.name)
        );
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\":{}", escape(k), fmt_num(*v));
        }
        out.push_str("}}");
        out
    }

    /// Parse back from [`Report::to_json`] output. Metric order is not
    /// preserved (JSON objects are unordered); keys come back sorted.
    pub fn from_json(j: &Json) -> crate::Result<Report> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("report: missing \"kind\""))?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("report: missing \"name\""))?;
        let obj = j
            .get("metrics")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("report: missing \"metrics\" object"))?;
        let mut metrics: Vec<(String, f64)> = Vec::with_capacity(obj.len());
        for (k, v) in obj {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("report: metric {k:?} is not a number"))?;
            metrics.push((k.clone(), x));
        }
        metrics.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(Report { kind: kind.to_string(), name: name.to_string(), metrics })
    }
}

/// Render reports as a JSON array, one report per line.
pub fn reports_to_json(reports: &[Report]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.to_json());
        if i + 1 < reports.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn stage_names_round_trip_and_are_unique() {
        let all: Vec<Stage> = Stage::PIPELINE
            .into_iter()
            .chain(Stage::FAULT_EVENTS)
            .chain(Stage::QUERY_EVENTS)
            .chain(Stage::OVERLOAD_EVENTS)
            .collect();
        for s in &all {
            assert_eq!(Stage::parse(s.as_str()), Some(*s));
        }
        let mut names: Vec<&str> = all.iter().map(|s| s.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len());
        assert_eq!(Stage::parse("nonsense"), None);
        assert!(Stage::Retry.is_fault_event());
        assert!(!Stage::Queue.is_fault_event());
        assert!(!Stage::QueryAdmit.is_fault_event());
        assert_eq!(Stage::parse("query_admit"), Some(Stage::QueryAdmit));
        assert_eq!(Stage::parse("query_retire"), Some(Stage::QueryRetire));
        assert_eq!(Stage::parse("shed"), Some(Stage::Shed));
        assert_eq!(Stage::parse("circuit_open"), Some(Stage::CircuitOpen));
        assert!(!Stage::Shed.is_fault_event(), "shed is an overload event, not recovery");
    }

    #[test]
    fn counter_gauge_accessors() {
        let reg = Registry::new();
        reg.inc("surveiledge_x_total", &[("scheme", "SE")], 2);
        reg.inc("surveiledge_x_total", &[("scheme", "SE")], 3);
        reg.gauge_set("surveiledge_g", &[], 1.5);
        assert_eq!(reg.counter("surveiledge_x_total", &[("scheme", "SE")]), 5);
        assert_eq!(reg.counter("surveiledge_x_total", &[("scheme", "other")]), 0);
        assert_eq!(reg.gauge("surveiledge_g", &[]), Some(1.5));
        // Label order must not matter for series identity.
        reg.inc("surveiledge_y_total", &[("a", "1"), ("b", "2")], 1);
        reg.inc("surveiledge_y_total", &[("b", "2"), ("a", "1")], 1);
        assert_eq!(reg.counter("surveiledge_y_total", &[("a", "1"), ("b", "2")]), 2);
    }

    #[test]
    fn merge_from_folds_counters_gauges_histograms_events() {
        let a = Registry::new();
        let b = Registry::new();
        a.inc("surveiledge_x_total", &[("scheme", "SE")], 2);
        b.inc("surveiledge_x_total", &[("scheme", "SE")], 3);
        b.inc("surveiledge_x_total", &[("scheme", "edge-only")], 1);
        a.gauge_set("surveiledge_g", &[], 1.0);
        b.gauge_set("surveiledge_g", &[], 2.5);
        a.observe("surveiledge_h_seconds", &[], 0.010);
        b.observe("surveiledge_h_seconds", &[], 0.020);
        b.observe("surveiledge_h2_seconds", &[], 0.5);
        let ev = |t: f64| SpanEvent {
            t,
            task: 0,
            stage: Stage::Detect,
            node: 1,
            dur: 0.0,
            scheme: "SE".to_string(),
            detail: String::new(),
        };
        a.span(ev(1.0));
        b.span(ev(2.0));
        b.span(ev(3.0));
        a.merge_from(&b);
        assert_eq!(a.counter("surveiledge_x_total", &[("scheme", "SE")]), 5);
        assert_eq!(a.counter("surveiledge_x_total", &[("scheme", "edge-only")]), 1);
        assert_eq!(a.gauge("surveiledge_g", &[]), Some(2.5), "gauge: last merge wins");
        assert_eq!(a.histogram("surveiledge_h_seconds", &[]).unwrap().count, 2);
        assert_eq!(a.histogram("surveiledge_h2_seconds", &[]).unwrap().count, 1);
        let ts: Vec<f64> = a.events().iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![1.0, 2.0, 3.0], "events append in source order");
        // Merging a clone of self is a no-op, not a double-count.
        let a2 = a.clone();
        a.merge_from(&a2);
        assert_eq!(a.counter("surveiledge_x_total", &[("scheme", "SE")]), 5);
        assert_eq!(a.event_count(), 3);
    }

    #[test]
    fn prometheus_export_exact_for_counters_and_gauges() {
        let reg = Registry::new();
        reg.inc("surveiledge_tasks_total", &[("scheme", "SE")], 7);
        reg.inc("surveiledge_tasks_total", &[("scheme", "edge-only")], 3);
        reg.gauge_set("surveiledge_accuracy", &[("scheme", "SE")], 0.875);
        let text = reg.export_prometheus();
        assert_eq!(
            text,
            "# TYPE surveiledge_tasks_total counter\n\
             surveiledge_tasks_total{scheme=\"SE\"} 7\n\
             surveiledge_tasks_total{scheme=\"edge-only\"} 3\n\
             # TYPE surveiledge_accuracy gauge\n\
             surveiledge_accuracy{scheme=\"SE\"} 0.875\n"
        );
        validate_prometheus(&text).unwrap();
    }

    #[test]
    fn prometheus_histogram_is_cumulative_and_valid() {
        let reg = Registry::new();
        for v in [0.0005, 0.003, 0.003, 10.0] {
            reg.observe("surveiledge_stage_seconds", &[("stage", "queue")], v);
        }
        let text = reg.export_prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("# TYPE surveiledge_stage_seconds histogram"));
        // First bound is 0.001 -> one observation at or below it.
        assert!(text.contains("surveiledge_stage_seconds_bucket{stage=\"queue\",le=\"0.001\"} 1\n"));
        assert!(text.contains("surveiledge_stage_seconds_bucket{stage=\"queue\",le=\"+Inf\"} 4\n"));
        assert!(text.contains("surveiledge_stage_seconds_count{stage=\"queue\"} 4\n"));
        // Cumulative counts never decrease down the bucket ladder.
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-monotone bucket line: {line}");
            prev = v;
        }
    }

    #[test]
    fn validator_rejects_bad_names_and_duplicates() {
        assert!(validate_prometheus("# TYPE Bad_Name counter\n").is_err());
        assert!(validate_prometheus("# TYPE x counter\nx 1\nx 2\n").is_err());
        assert!(validate_prometheus("x 1\n").is_err(), "sample without TYPE");
        assert!(validate_prometheus("# TYPE x counter\nx{a=\"1\"} 1\nx{a=\"2\"} 2\n").is_ok());
        assert!(
            validate_prometheus("# TYPE x counter\nx{a=\"1\"} 1\nx{a=\"1\"} 2\n").is_err(),
            "duplicate labelled series"
        );
        assert!(validate_prometheus("# TYPE x counter\nx notanumber\n").is_err());
        assert!(validate_prometheus("# TYPE x wibble\n").is_err());
    }

    #[test]
    fn jsonl_export_round_trips_through_runtime_json() {
        let reg = Registry::new();
        reg.span(SpanEvent {
            t: 1.5,
            task: 3,
            stage: Stage::EdgeInfer,
            node: 1,
            dur: 0.28,
            scheme: "SurveilEdge".to_string(),
            detail: String::new(),
        });
        reg.span(SpanEvent {
            t: 2.0,
            task: 3,
            stage: Stage::ThresholdDecide,
            node: 1,
            dur: 0.0,
            scheme: "SurveilEdge".to_string(),
            detail: "doubtful".to_string(),
        });
        let text = reg.export_jsonl();
        assert_eq!(validate_jsonl(&text).unwrap(), 2);
        let first = text.lines().next().unwrap();
        let j = Json::parse(first).unwrap();
        assert_eq!(j.get("task").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("stage").and_then(Json::as_str), Some("edge_infer"));
        assert_eq!(j.get("dur").and_then(Json::as_f64), Some(0.28));
        assert!(validate_jsonl("{\"t\":1}\n").is_err(), "span schema enforced");
        assert!(validate_jsonl("").unwrap() == 0);
    }

    #[test]
    fn prop_histogram_counts_match_brute_force_oracle() {
        check("hist_count_oracle", |rng, _| {
            let mut h = Histogram::log_bucketed(1e-3, 2.0, 12);
            let n = rng.range_usize(0, 200);
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let v = rng.lognormal3(-1.0, 1.0, 0.0);
                vals.push(v);
                h.observe(v);
            }
            assert_eq!(h.count(), n as u64);
            assert_eq!(h.bucket_counts().iter().sum::<u64>(), n as u64, "count preserved");
            // Brute-force oracle: count each (lower, upper] range directly.
            let bounds = h.bounds();
            for (i, &ub) in bounds.iter().enumerate() {
                let expect = vals
                    .iter()
                    .filter(|&&v| v <= ub && (i == 0 || v > bounds[i - 1]))
                    .count() as u64;
                assert_eq!(h.bucket_counts()[i], expect, "bucket {i}");
            }
            let overflow =
                vals.iter().filter(|&&v| v > bounds[bounds.len() - 1]).count() as u64;
            assert_eq!(h.bucket_counts()[bounds.len()], overflow, "overflow bucket");
        });
    }

    #[test]
    fn prop_histogram_merge_is_associative() {
        check("hist_merge_assoc", |rng, _| {
            let mut mk = |rng: &mut Rng| {
                let mut h = Histogram::log_bucketed(1e-3, 2.0, 10);
                for _ in 0..rng.range_usize(0, 50) {
                    h.observe(rng.lognormal3(-1.0, 0.8, 0.0));
                }
                h
            };
            let a = mk(rng);
            let b = mk(rng);
            let c = mk(rng);
            let mut ab_c = a.clone();
            ab_c.merge(&b);
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            assert_eq!(ab_c.bucket_counts(), a_bc.bucket_counts());
            assert_eq!(ab_c.count(), a_bc.count());
            assert_eq!(ab_c.count(), a.count() + b.count() + c.count());
            assert!((ab_c.sum() - a_bc.sum()).abs() <= 1e-9 * (1.0 + ab_c.sum().abs()));
        });
    }

    #[test]
    fn report_json_round_trip() {
        let mut r = Report::new("scheme_run", "SurveilEdge");
        r.push("accuracy_f2", 0.875);
        r.push("tasks", 120.0);
        r.push("accuracy_f2", 0.9); // replaces, keeps order
        let j = Json::parse(&r.to_json()).unwrap();
        let back = Report::from_json(&j).unwrap();
        assert_eq!(back.kind, "scheme_run");
        assert_eq!(back.name, "SurveilEdge");
        assert_eq!(back.get("accuracy_f2"), Some(0.9));
        assert_eq!(back.get("tasks"), Some(120.0));
        assert_eq!(back.metrics().len(), r.metrics().len());
        // Array form parses too.
        let arr = reports_to_json(&[r.clone(), r]);
        let j = Json::parse(&arr).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn node_labels() {
        assert_eq!(node_label(0), "cloud");
        assert_eq!(node_label(2), "edge2");
    }

    #[test]
    fn write_obs_dir_creates_missing_nested_dirs() {
        let dir = std::env::temp_dir()
            .join(format!("surveiledge_obs_{}", std::process::id()))
            .join("does/not/exist/yet");
        let _ = std::fs::remove_dir_all(&dir);
        let reg = Registry::new();
        reg.inc("surveiledge_x_total", &[("scheme", "SE")], 1);
        let report = Report::new("scheme_run", "SE");
        write_obs_dir(&dir, &reg, &[report]).unwrap();
        for f in ["events.jsonl", "metrics.prom", "report.json"] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
        let arr = Json::parse(&std::fs::read_to_string(dir.join("report.json")).unwrap()).unwrap();
        assert_eq!(arr.as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
