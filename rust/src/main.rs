//! `surveiledge` CLI launcher.
//!
//! Subcommands:
//!   run       — run one scheme on a scenario config, print the table row
//!   tables    — reproduce the paper's Tables II/III/IV (all 4 schemes,
//!               run concurrently on scoped threads; results are
//!               byte-identical to a sequential run at the same seed)
//!   query     — run a multi-query spec file: admission control, shared
//!               detect/classify work, streaming per-query results
//!   offline   — run the offline stage (profiles, clusters, datasets)
//!   inspect   — print the artifact manifest summary
//!   obs-check — validate an `--obs-out` export directory
//!   help      — usage
//!
//! (clap is not in the offline vendor set; flags are parsed by hand.)

use std::path::Path;

use surveiledge::config::{Config, Scheme};
use surveiledge::coordinator::{offline_stage, OfflineConfig};
use surveiledge::harness::{run_all_schemes, standard_mode, Harness, RunSpec, ServiceTimes, HD_SCALE};
use surveiledge::metrics::render_table;
use surveiledge::obs::{self, Registry, Report};
use surveiledge::query::{write_results, AdmissionModel, QueryFile, QueryRegistry};
use surveiledge::runtime::json::Json;
use surveiledge::runtime::service::InferenceService;
use surveiledge::runtime::Manifest;
use surveiledge::video::standard_deployment;

const USAGE: &str = "\
surveiledge — real-time cloud-edge video query (SurveilEdge reproduction)

USAGE:
  surveiledge run       [--config FILE] [--scheme NAME] [--pjrt] [--duration SECS] [--obs-out DIR]
  surveiledge tables    [--setting single|homogeneous|heterogeneous] [--pjrt] [--duration SECS] [--obs-out DIR]
  surveiledge query     [--spec FILE] [--scheme NAME] [--pjrt] [--duration SECS] [--obs-out DIR]
  surveiledge offline   [--cameras N] [--duration SECS] [--artifacts DIR] [--obs-out DIR]
  surveiledge inspect   [--artifacts DIR]
  surveiledge obs-check DIR
  surveiledge help

Schemes: SurveilEdge | fixed | edge-only | cloud-only
`tables` runs all four schemes in parallel (one thread per scheme); per-scheme
results and exports are identical to running them one at a time.
--pjrt runs every classification through the PJRT artifacts (needs `make artifacts`);
without it, calibrated synthetic confidences are used.
--obs-out DIR writes events.jsonl (per-task stage spans), metrics.prom
(Prometheus text exposition) and report.json (stable result schema) into DIR
(created if missing); `obs-check DIR` validates all three.
`query` runs a multi-query spec file ([[query]] blocks + [admission] headroom,
see rust/configs/queries.toml): queries pass load-aware admission control, share one
detect + edge-classify pass per frame, and stream per-query verdicts; with
--obs-out DIR each query also exports a deterministic query_<id>.jsonl.
An [overload] block (see rust/configs/overload.toml) turns on overload control:
bounded node/uplink queues with deadline-class-aware shedding (batch first,
interactive last), a per-uplink circuit breaker, and a degradation ladder
(subsample -> edge-local verdicts -> shed). Configs without the block behave
byte-identically to earlier releases.";

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn load_config(args: &[String]) -> anyhow::Result<Config> {
    let mut cfg = match arg_value(args, "--config") {
        Some(path) => Config::from_file(Path::new(&path))?,
        None => match arg_value(args, "--setting").as_deref() {
            Some("homogeneous") => Config::homogeneous(),
            Some("heterogeneous") => Config::heterogeneous(),
            _ => Config::single_edge(),
        },
    };
    if let Some(d) = arg_value(args, "--duration") {
        cfg.duration = d.parse()?;
    }
    if let Some(dir) = arg_value(args, "--artifacts") {
        cfg.artifacts = dir;
    }
    Ok(cfg)
}

/// Write the registry exports plus `report.json` into `--obs-out DIR`
/// (created, including parents, if missing).
fn write_obs(dir: &str, reg: &Registry, reports: &[Report]) -> anyhow::Result<()> {
    let dir = Path::new(dir);
    obs::write_obs_dir(dir, reg, reports)?;
    println!(
        "obs: wrote events.jsonl ({} spans), metrics.prom, report.json to {}",
        reg.event_count(),
        dir.display()
    );
    Ok(())
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let scheme = arg_value(args, "--scheme")
        .and_then(|s| Scheme::from_name(&s))
        .unwrap_or(Scheme::SurveilEdge);
    let mode = standard_mode(&cfg, has_flag(args, "--pjrt"))?;
    let obs_out = arg_value(args, "--obs-out");
    let overload_on = cfg.overload.enabled;
    let reg = Registry::new();
    let mut builder = Harness::builder(cfg).mode(mode);
    if obs_out.is_some() {
        builder = builder.observe(reg.clone());
    }
    let mut h = builder.build();
    let r = h.run(scheme)?;
    println!("{}", render_table("result", std::slice::from_ref(&r.row)));
    println!(
        "tasks={} uploads={} p50={:.3}s p99={:.3}s std={:.3}s",
        r.tasks,
        r.uploads,
        r.latency.percentile(0.5),
        r.latency.percentile(0.99),
        r.latency.std()
    );
    if overload_on {
        println!(
            "overload: shed={} degraded={} retried={} lost={}",
            r.faults.shed, r.faults.degraded, r.faults.retried, r.faults.lost
        );
    }
    if let Some(dir) = obs_out {
        write_obs(&dir, &reg, &[r.report()])?;
    }
    Ok(())
}

fn cmd_tables(args: &[String]) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let pjrt = has_flag(args, "--pjrt");
    let obs_out = arg_value(args, "--obs-out");
    let title = match cfg.edges.len() {
        1 => "Table II — single edge and cloud",
        _ if cfg.edges.iter().all(|e| (e.speed - cfg.edges[0].speed).abs() < 1e-9) => {
            "Table III — homogeneous edges and cloud"
        }
        _ => "Table IV — heterogeneous edges and cloud",
    };
    let reg = Registry::new();
    let mut spec = RunSpec::new(cfg).pjrt(pjrt);
    if obs_out.is_some() {
        spec = spec.observe(reg.clone());
    }
    let results = run_all_schemes(&spec)?;
    let rows: Vec<_> = results.iter().map(|r| r.row.clone()).collect();
    println!("{}", render_table(title, &rows));
    if let Some(dir) = obs_out {
        let reports: Vec<Report> = results.iter().map(|r| r.report()).collect();
        write_obs(&dir, &reg, &reports)?;
    }
    Ok(())
}

/// Run a multi-query spec: admission control over the `[[query]]` blocks,
/// one shared pipeline run for the admitted set, per-query streams out.
fn cmd_query(args: &[String]) -> anyhow::Result<()> {
    let spec_path =
        arg_value(args, "--spec").unwrap_or_else(|| "rust/configs/queries.toml".into());
    let qf = QueryFile::from_file(Path::new(&spec_path))?;
    let mut cfg = qf.cfg;
    if let Some(d) = arg_value(args, "--duration") {
        cfg.duration = d.parse()?;
    }
    let scheme = arg_value(args, "--scheme")
        .and_then(|s| Scheme::from_name(&s))
        .unwrap_or(Scheme::SurveilEdge);
    let obs_out = arg_value(args, "--obs-out");
    let reg = Registry::new();

    // Admission control: every query passes the projected-load gate
    // before the run starts; rejections are reported, not fatal.
    let model = AdmissionModel::from_config(
        &cfg,
        ServiceTimes::default().edge_infer,
        24 * 24 * 3 * HD_SCALE,
    );
    let registry = QueryRegistry::new(model, qf.headroom);
    registry.attach_registry(reg.clone());
    for spec in qf.queries {
        let id = spec.id.clone();
        match registry.admit(spec, 0.0) {
            Ok(()) => println!(
                "admitted query {id:?} (projected load {:.3}, headroom {:.3})",
                registry.projected_load(),
                qf.headroom
            ),
            Err(e) => eprintln!("warning: {e:#}"),
        }
    }
    anyhow::ensure!(!registry.is_empty(), "no queries admitted from {spec_path}");
    let queries = registry.snapshot();

    let mode = standard_mode(&cfg, has_flag(args, "--pjrt"))?;
    let overload_on = cfg.overload.enabled;
    let mut h = Harness::builder(cfg)
        .mode(mode)
        .observe(reg.clone())
        .queries(queries.clone())
        .build();
    let r = h.run(scheme)?;
    println!("{}", render_table("result", std::slice::from_ref(&r.row)));
    for q in &r.per_query {
        println!(
            "  query {:<16} verdicts={:<6} positives={:<6} cloud={:<5} local={:<5} shed={:<5} mean_latency={:.3}s",
            q.name,
            q.get("verdicts").unwrap_or(0.0),
            q.get("positives").unwrap_or(0.0),
            q.get("doubtful_cloud").unwrap_or(0.0),
            q.get("doubtful_local").unwrap_or(0.0),
            q.get("shed").unwrap_or(0.0),
            q.get("mean_latency_s").unwrap_or(0.0),
        );
    }
    if overload_on {
        println!(
            "overload: shed={} degraded={} retried={} lost={}",
            r.faults.shed, r.faults.degraded, r.faults.retried, r.faults.lost
        );
    }
    if let Some(dir) = obs_out {
        let paths = write_results(Path::new(&dir), &r.query_verdicts, queries.specs())?;
        println!("query: wrote {} per-query JSONL stream(s) to {dir}", paths.len());
        let mut reports = vec![r.report()];
        reports.extend(r.per_query);
        write_obs(&dir, &reg, &reports)?;
    }
    Ok(())
}

fn cmd_offline(args: &[String]) -> anyhow::Result<()> {
    let n: usize = arg_value(args, "--cameras").and_then(|v| v.parse().ok()).unwrap_or(6);
    let duration: f64 = arg_value(args, "--duration").and_then(|v| v.parse().ok()).unwrap_or(60.0);
    let artifacts = arg_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let svc = InferenceService::spawn(artifacts.into(), vec![1])?;
    let mut cams = standard_deployment(n, 96, 128, 33);
    let stage = offline_stage(
        &mut cams,
        &svc.handle,
        &OfflineConfig { duration, ..OfflineConfig::default() },
    )?;
    println!("camera profiles (proportion vectors):");
    for p in &stage.profiles {
        let v: Vec<String> = p.proportions.iter().map(|x| format!("{x:.2}")).collect();
        println!(
            "  cam{:<2} cluster {} [{}]",
            p.camera.0,
            stage.clustering.assignment[p.camera.0 as usize],
            v.join(" ")
        );
    }
    for (i, ds) in stage.datasets.iter().enumerate() {
        println!("cluster {i}: {} labeled crops", ds.crops.len());
    }
    if let Some(dir) = arg_value(args, "--obs-out") {
        let reg = Registry::new();
        reg.gauge_set("surveiledge_offline_cameras", &[], n as f64);
        reg.gauge_set("surveiledge_offline_clusters", &[], stage.datasets.len() as f64);
        for (i, ds) in stage.datasets.iter().enumerate() {
            let cluster = i.to_string();
            reg.inc(
                "surveiledge_offline_crops_total",
                &[("cluster", cluster.as_str())],
                ds.crops.len() as u64,
            );
        }
        svc.handle.stats()?.export_into(&reg);
        let mut report = Report::new("offline_stage", "offline");
        report.push("cameras", n as f64);
        report.push("clusters", stage.datasets.len() as f64);
        report.push(
            "crops",
            stage.datasets.iter().map(|d| d.crops.len()).sum::<usize>() as f64,
        );
        write_obs(&dir, &reg, &[report])?;
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> anyhow::Result<()> {
    let dir = arg_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let m = Manifest::load(Path::new(&dir))?;
    println!("artifact bundle at {dir}:");
    println!("  img={}x{}x3  frame={}x{}", m.img, m.img, m.frame_h, m.frame_w);
    println!("  classes: {}", m.classes.join(", "));
    println!("  edge params: {} tensors", m.edge_params.len());
    println!("  cloud params: {} tensors", m.cloud_params.len());
    let mut names: Vec<_> = m.artifacts.keys().collect();
    names.sort();
    for name in names {
        println!("  artifact {name} -> {}", m.artifacts[name]);
    }
    Ok(())
}

/// Validate an `--obs-out` directory: metrics.prom against the Prometheus
/// exposition rules (naming, TYPE declarations, no duplicate series),
/// events.jsonl line-by-line through `runtime::json`, and report.json
/// against the [`Report`] schema if present.
fn cmd_obs_check(args: &[String]) -> anyhow::Result<()> {
    let dir = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: surveiledge obs-check DIR"))?;
    let dir = Path::new(&dir);
    let prom = std::fs::read_to_string(dir.join("metrics.prom"))?;
    obs::validate_prometheus(&prom)?;
    println!("metrics.prom: valid exposition ({} lines)", prom.lines().count());
    let jsonl = std::fs::read_to_string(dir.join("events.jsonl"))?;
    let spans = obs::validate_jsonl(&jsonl)?;
    println!("events.jsonl: {spans} span event(s), all parse");
    let report_path = dir.join("report.json");
    if report_path.exists() {
        let text = std::fs::read_to_string(&report_path)?;
        let j = Json::parse(&text)?;
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("report.json: expected a JSON array"))?;
        for item in arr {
            Report::from_json(item)?;
        }
        println!("report.json: {} report(s) round-trip", arr.len());
    }
    println!("obs-check: OK");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("tables") => cmd_tables(&args[1..]),
        Some("query") => cmd_query(&args[1..]),
        Some("offline") => cmd_offline(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("obs-check") => cmd_obs_check(&args[1..]),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
