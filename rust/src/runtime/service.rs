//! Inference service: a dedicated thread that owns the PJRT engine.
//!
//! `xla::PjRtClient` is `Rc`-based and thread-bound, but the serving system
//! is multi-threaded (edge/cloud node event loops). The service thread owns
//! the engine and every compiled model; node threads talk to it through a
//! cloneable [`ServiceHandle`] (bounded channel + reply channels) — the
//! same shape a production system has around a single accelerator worker.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use super::{Engine, ModelRunner, MomentumSgd, ServiceStats};

/// Requests the service understands.
enum Request {
    /// Classify one crop with edge `edge_id`'s deployed CQ-CNN.
    EdgeInfer { edge_id: u32, pixels: Vec<f32>, reply: SyncSender<crate::Result<Vec<f32>>> },
    /// Classify one crop with the cloud CNN (8-class probs).
    CloudInfer { pixels: Vec<f32>, reply: SyncSender<crate::Result<Vec<f32>>> },
    /// Deploy (fine-tuned) edge weights for `edge_id`.
    DeployEdge { edge_id: u32, params: Vec<Vec<f32>>, reply: SyncSender<crate::Result<()>> },
    /// Run `steps` of head-group fine-tuning on the given dataset and
    /// deploy nothing (caller decides); returns final params + loss curve.
    FineTune {
        pixels: Vec<f32>,
        labels: Vec<i32>,
        steps: usize,
        lr: f32,
        full: bool,
        reply: SyncSender<crate::Result<FineTuneResult>>,
    },
    /// Frame-difference dense stage via the HLO artifact.
    FrameDiff {
        prev: Vec<f32>,
        cur: Vec<f32>,
        nxt: Vec<f32>,
        reply: SyncSender<crate::Result<Vec<u8>>>,
    },
    Stats { reply: SyncSender<ServiceSnapshot> },
    Shutdown,
}

/// Fine-tuning output.
#[derive(Clone, Debug)]
pub struct FineTuneResult {
    pub params: Vec<Vec<f32>>,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    pub train_secs: f64,
}

/// Aggregate service-side measurements.
#[derive(Clone, Debug, Default)]
pub struct ServiceSnapshot {
    pub edge_infer: ServiceStats,
    pub cloud_infer: ServiceStats,
    pub train: ServiceStats,
    pub framediff: ServiceStats,
}

/// Cloneable, Send handle to the service thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Request>,
}

impl ServiceHandle {
    fn call<T>(&self, build: impl FnOnce(SyncSender<crate::Result<T>>) -> Request) -> crate::Result<T>
    where
        T: Send + 'static,
    {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(build(reply))
            .map_err(|_| anyhow::anyhow!("inference service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("inference service dropped reply"))?
    }

    /// Edge classify: returns `[p_not_query, p_query]`.
    pub fn edge_infer(&self, edge_id: u32, pixels: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.call(|reply| Request::EdgeInfer { edge_id, pixels, reply })
    }

    /// Cloud classify: returns 8-class probabilities.
    pub fn cloud_infer(&self, pixels: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.call(|reply| Request::CloudInfer { pixels, reply })
    }

    pub fn deploy_edge(&self, edge_id: u32, params: Vec<Vec<f32>>) -> crate::Result<()> {
        self.call(|reply| Request::DeployEdge { edge_id, params, reply })
    }

    pub fn fine_tune(
        &self,
        pixels: Vec<f32>,
        labels: Vec<i32>,
        steps: usize,
        lr: f32,
        full: bool,
    ) -> crate::Result<FineTuneResult> {
        self.call(|reply| Request::FineTune { pixels, labels, steps, lr, full, reply })
    }

    pub fn framediff(&self, prev: Vec<f32>, cur: Vec<f32>, nxt: Vec<f32>) -> crate::Result<Vec<u8>> {
        self.call(|reply| Request::FrameDiff { prev, cur, nxt, reply })
    }

    pub fn stats(&self) -> crate::Result<ServiceSnapshot> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow::anyhow!("inference service is down"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// The running service (join on drop).
pub struct InferenceService {
    pub handle: ServiceHandle,
    worker: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Spawn the service: loads the engine, compiles edge models for
    /// `edge_ids` (all starting from the pretrained weights), the cloud
    /// model, the trainer, and the framediff kernel.
    pub fn spawn(artifact_dir: PathBuf, edge_ids: Vec<u32>) -> crate::Result<InferenceService> {
        let (tx, rx) = sync_channel::<Request>(256);
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);
        let worker = std::thread::Builder::new()
            .name("inference-service".into())
            .spawn(move || worker_main(artifact_dir, edge_ids, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("service thread died during init"))??;
        Ok(InferenceService { handle: ServiceHandle { tx }, worker: Some(worker) })
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_main(
    artifact_dir: PathBuf,
    edge_ids: Vec<u32>,
    rx: Receiver<Request>,
    ready: SyncSender<crate::Result<()>>,
) {
    let setup = (|| -> crate::Result<_> {
        let engine = Engine::new(&artifact_dir)?;
        let pretrained = engine.edge_pretrained()?;
        let mut edge_models: HashMap<u32, ModelRunner> = HashMap::new();
        for id in &edge_ids {
            edge_models.insert(*id, engine.edge_model(1, &pretrained)?);
        }
        let cloud = engine.cloud_model(1, &engine.cloud_trained()?)?;
        let trainer = engine.trainer()?;
        let framediff = engine.framediff()?;
        Ok((engine, pretrained, edge_models, cloud, trainer, framediff))
    })();

    let (engine, pretrained, mut edge_models, cloud, trainer, framediff) = match setup {
        Ok(parts) => {
            let _ = ready.send(Ok(()));
            parts
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::EdgeInfer { edge_id, pixels, reply } => {
                let r = edge_models
                    .get(&edge_id)
                    .ok_or_else(|| anyhow::anyhow!("unknown edge {edge_id}"))
                    .and_then(|m| m.infer(&pixels))
                    .map(|rows| rows.into_iter().next().unwrap_or_default());
                let _ = reply.send(r);
            }
            Request::CloudInfer { pixels, reply } => {
                let r = cloud.infer(&pixels).map(|rows| rows.into_iter().next().unwrap_or_default());
                let _ = reply.send(r);
            }
            Request::DeployEdge { edge_id, params, reply } => {
                let r = match edge_models.get_mut(&edge_id) {
                    Some(m) => m.set_params(&params),
                    None => engine.edge_model(1, &params).map(|m| {
                        edge_models.insert(edge_id, m);
                    }),
                };
                let _ = reply.send(r);
            }
            Request::FineTune { pixels, labels, steps, lr, full, reply } => {
                let r = run_fine_tune(&engine, &trainer, &pretrained, &pixels, &labels, steps, lr, full);
                let _ = reply.send(r);
            }
            Request::FrameDiff { prev, cur, nxt, reply } => {
                let _ = reply.send(framediff.mask(&prev, &cur, &nxt));
            }
            Request::Stats { reply } => {
                let agg_edge = edge_models.values().fold(ServiceStats::default(), |mut acc, m| {
                    let s = m.stats();
                    acc.calls += s.calls;
                    acc.total_secs += s.total_secs;
                    acc.max_secs = acc.max_secs.max(s.max_secs);
                    acc
                });
                let _ = reply.send(ServiceSnapshot {
                    edge_infer: agg_edge,
                    cloud_infer: cloud.stats(),
                    train: trainer.stats(),
                    framediff: framediff.stats(),
                });
            }
            Request::Shutdown => break,
        }
    }
}

/// The online fine-tuning loop (paper §IV-B): start from pretrained
/// weights, run momentum-SGD on the context-specific dataset. `full=false`
/// updates only the head group ("SurveilEdge" scheme); `full=true` trains
/// everything from scratch ("All Fine-tune" baseline).
#[allow(clippy::too_many_arguments)]
fn run_fine_tune(
    engine: &Engine,
    trainer: &super::TrainRunner,
    pretrained: &[Vec<f32>],
    pixels: &[f32],
    labels: &[i32],
    steps: usize,
    lr: f32,
    full: bool,
) -> crate::Result<FineTuneResult> {
    let t0 = std::time::Instant::now();
    let n = engine.manifest.edge_params.len();
    let (mut params, mask) = if full {
        // From-scratch: deterministic pseudo-random re-init of all params.
        let mut rng = crate::testkit::Rng::new(0xF17E_7A11);
        let params: Vec<Vec<f32>> = engine
            .manifest
            .edge_params
            .iter()
            .map(|s| {
                let fan_in: usize = s.shape[..s.shape.len().saturating_sub(1)]
                    .iter()
                    .product::<usize>()
                    .max(1);
                let std = (2.0 / fan_in as f64).sqrt() as f32;
                (0..s.numel())
                    .map(|_| if s.name.ends_with("_b") { 0.0 } else { rng.normal() as f32 * std })
                    .collect()
            })
            .collect();
        (params, vec![true; n])
    } else {
        (pretrained.to_vec(), MomentumSgd::head_only_mask(n, engine.manifest.edge_head_group))
    };

    let mut opt = MomentumSgd::new(&engine.manifest.edge_params, lr, mask);
    let batch = trainer.batch;
    let px_per = trainer.img * trainer.img * 3;
    let total = labels.len();
    anyhow::ensure!(total >= batch, "fine-tune dataset smaller than batch ({total} < {batch})");
    anyhow::ensure!(pixels.len() == total * px_per, "pixels/labels mismatch");

    let mut losses = Vec::with_capacity(steps);
    let mut accs = Vec::with_capacity(steps);
    let mut rng = crate::testkit::Rng::new(0x7EA1_5EED);
    let mut bpix = vec![0.0f32; batch * px_per];
    let mut blab = vec![0i32; batch];
    for _ in 0..steps {
        for j in 0..batch {
            let k = rng.range_usize(0, total);
            bpix[j * px_per..(j + 1) * px_per].copy_from_slice(&pixels[k * px_per..(k + 1) * px_per]);
            blab[j] = labels[k];
        }
        let out = trainer.grad_step(&params, &bpix, &blab)?;
        losses.push(out.loss);
        accs.push(out.acc);
        opt.step(&mut params, &out.grads);
    }
    Ok(FineTuneResult { params, losses, accs, train_secs: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    // Service tests require artifacts; they live in
    // rust/tests/pipeline_integration.rs so `cargo test --lib` stays fast.
}
