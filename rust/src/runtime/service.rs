//! Inference service: a dedicated thread that owns the classification
//! backend.
//!
//! `xla::PjRtClient` is `Rc`-based and thread-bound, but the serving system
//! is multi-threaded (edge/cloud node event loops). The service thread owns
//! the backend and every model; node threads talk to it through a
//! cloneable [`ServiceHandle`] (bounded channel + reply channels) — the
//! same shape a production system has around a single accelerator worker.
//!
//! Two backends, selected at build time:
//!
//! * with `--features pjrt`, the worker owns the PJRT engine and serves the
//!   AOT HLO artifacts;
//! * otherwise it serves the pure-Rust [`super::reference`] classifier —
//!   no artifacts, no XLA, deterministic template-matching CNN stand-in —
//!   so `surveiledge offline` and the examples run in a default build.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use super::ServiceStats;
#[cfg(feature = "pjrt")]
use super::{Engine, ModelRunner, MomentumSgd};

/// Requests the service understands.
enum Request {
    /// Classify one crop with edge `edge_id`'s deployed CQ-CNN.
    EdgeInfer { edge_id: u32, pixels: Vec<f32>, reply: SyncSender<crate::Result<Vec<f32>>> },
    /// Classify one crop with the cloud CNN (8-class probs).
    CloudInfer { pixels: Vec<f32>, reply: SyncSender<crate::Result<Vec<f32>>> },
    /// Deploy (fine-tuned) edge weights for `edge_id`.
    DeployEdge { edge_id: u32, params: Vec<Vec<f32>>, reply: SyncSender<crate::Result<()>> },
    /// Run `steps` of head-group fine-tuning on the given dataset and
    /// deploy nothing (caller decides); returns final params + loss curve.
    FineTune {
        pixels: Vec<f32>,
        labels: Vec<i32>,
        steps: usize,
        lr: f32,
        full: bool,
        reply: SyncSender<crate::Result<FineTuneResult>>,
    },
    /// Frame-difference dense stage via the HLO artifact.
    FrameDiff {
        prev: Vec<f32>,
        cur: Vec<f32>,
        nxt: Vec<f32>,
        reply: SyncSender<crate::Result<Vec<u8>>>,
    },
    Stats { reply: SyncSender<ServiceSnapshot> },
    Shutdown,
}

/// Fine-tuning output.
#[derive(Clone, Debug)]
pub struct FineTuneResult {
    pub params: Vec<Vec<f32>>,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    pub train_secs: f64,
}

/// Aggregate service-side measurements.
#[derive(Clone, Debug, Default)]
pub struct ServiceSnapshot {
    pub edge_infer: ServiceStats,
    pub cloud_infer: ServiceStats,
    pub train: ServiceStats,
    pub framediff: ServiceStats,
}

impl ServiceSnapshot {
    /// Export the snapshot into a metric registry, one `op` label per
    /// service operation. A snapshot is a point-in-time total, so export
    /// it once per run (counters would double on a second export).
    pub fn export_into(&self, reg: &crate::obs::Registry) {
        let ops: [(&str, &ServiceStats); 4] = [
            ("edge_infer", &self.edge_infer),
            ("cloud_infer", &self.cloud_infer),
            ("train", &self.train),
            ("framediff", &self.framediff),
        ];
        for (op, s) in ops {
            let l = [("op", op)];
            reg.inc("surveiledge_service_calls_total", &l, s.calls);
            reg.gauge_set("surveiledge_service_mean_seconds", &l, s.mean());
            reg.gauge_set("surveiledge_service_max_seconds", &l, s.max_secs);
        }
    }
}

/// Cloneable, Send handle to the service thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Request>,
}

impl ServiceHandle {
    fn call<T>(&self, build: impl FnOnce(SyncSender<crate::Result<T>>) -> Request) -> crate::Result<T>
    where
        T: Send + 'static,
    {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(build(reply))
            .map_err(|_| anyhow::anyhow!("inference service is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("inference service dropped reply"))?
    }

    /// Edge classify: returns `[p_not_query, p_query]`.
    pub fn edge_infer(&self, edge_id: u32, pixels: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.call(|reply| Request::EdgeInfer { edge_id, pixels, reply })
    }

    /// Cloud classify: returns 8-class probabilities.
    pub fn cloud_infer(&self, pixels: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.call(|reply| Request::CloudInfer { pixels, reply })
    }

    pub fn deploy_edge(&self, edge_id: u32, params: Vec<Vec<f32>>) -> crate::Result<()> {
        self.call(|reply| Request::DeployEdge { edge_id, params, reply })
    }

    pub fn fine_tune(
        &self,
        pixels: Vec<f32>,
        labels: Vec<i32>,
        steps: usize,
        lr: f32,
        full: bool,
    ) -> crate::Result<FineTuneResult> {
        self.call(|reply| Request::FineTune { pixels, labels, steps, lr, full, reply })
    }

    pub fn framediff(&self, prev: Vec<f32>, cur: Vec<f32>, nxt: Vec<f32>) -> crate::Result<Vec<u8>> {
        self.call(|reply| Request::FrameDiff { prev, cur, nxt, reply })
    }

    pub fn stats(&self) -> crate::Result<ServiceSnapshot> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow::anyhow!("inference service is down"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// The running service (join on drop).
pub struct InferenceService {
    pub handle: ServiceHandle,
    worker: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Spawn the service. With the `pjrt` feature this loads the engine and
    /// compiles edge models for `edge_ids` (all starting from the
    /// pretrained weights), the cloud model, the trainer, and the framediff
    /// kernel; without it, the worker serves the pure-Rust reference
    /// classifier and needs no artifacts on disk.
    pub fn spawn(artifact_dir: PathBuf, edge_ids: Vec<u32>) -> crate::Result<InferenceService> {
        let (tx, rx) = sync_channel::<Request>(256);
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<()>>(1);
        let worker = std::thread::Builder::new()
            .name("inference-service".into())
            .spawn(move || {
                #[cfg(feature = "pjrt")]
                worker_main(artifact_dir, edge_ids, rx, ready_tx);
                #[cfg(not(feature = "pjrt"))]
                reference_worker_main(artifact_dir, edge_ids, rx, ready_tx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("service thread died during init"))??;
        Ok(InferenceService { handle: ServiceHandle { tx }, worker: Some(worker) })
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(feature = "pjrt")]
fn worker_main(
    artifact_dir: PathBuf,
    edge_ids: Vec<u32>,
    rx: Receiver<Request>,
    ready: SyncSender<crate::Result<()>>,
) {
    let setup = (|| -> crate::Result<_> {
        let engine = Engine::new(&artifact_dir)?;
        let pretrained = engine.edge_pretrained()?;
        let mut edge_models: HashMap<u32, ModelRunner> = HashMap::new();
        for id in &edge_ids {
            edge_models.insert(*id, engine.edge_model(1, &pretrained)?);
        }
        let cloud = engine.cloud_model(1, &engine.cloud_trained()?)?;
        let trainer = engine.trainer()?;
        let framediff = engine.framediff()?;
        Ok((engine, pretrained, edge_models, cloud, trainer, framediff))
    })();

    let (engine, pretrained, mut edge_models, cloud, trainer, framediff) = match setup {
        Ok(parts) => {
            let _ = ready.send(Ok(()));
            parts
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::EdgeInfer { edge_id, pixels, reply } => {
                let r = edge_models
                    .get(&edge_id)
                    .ok_or_else(|| anyhow::anyhow!("unknown edge {edge_id}"))
                    .and_then(|m| m.infer(&pixels))
                    .map(|rows| rows.into_iter().next().unwrap_or_default());
                let _ = reply.send(r);
            }
            Request::CloudInfer { pixels, reply } => {
                let r = cloud.infer(&pixels).map(|rows| rows.into_iter().next().unwrap_or_default());
                let _ = reply.send(r);
            }
            Request::DeployEdge { edge_id, params, reply } => {
                let r = match edge_models.get_mut(&edge_id) {
                    Some(m) => m.set_params(&params),
                    None => engine.edge_model(1, &params).map(|m| {
                        edge_models.insert(edge_id, m);
                    }),
                };
                let _ = reply.send(r);
            }
            Request::FineTune { pixels, labels, steps, lr, full, reply } => {
                let r = run_fine_tune(&engine, &trainer, &pretrained, &pixels, &labels, steps, lr, full);
                let _ = reply.send(r);
            }
            Request::FrameDiff { prev, cur, nxt, reply } => {
                let _ = reply.send(framediff.mask(&prev, &cur, &nxt));
            }
            Request::Stats { reply } => {
                let agg_edge = edge_models.values().fold(ServiceStats::default(), |mut acc, m| {
                    let s = m.stats();
                    acc.calls += s.calls;
                    acc.total_secs += s.total_secs;
                    acc.max_secs = acc.max_secs.max(s.max_secs);
                    acc
                });
                let _ = reply.send(ServiceSnapshot {
                    edge_infer: agg_edge,
                    cloud_infer: cloud.stats(),
                    train: trainer.stats(),
                    framediff: framediff.stats(),
                });
            }
            Request::Shutdown => break,
        }
    }
}

/// The online fine-tuning loop (paper §IV-B): start from pretrained
/// weights, run momentum-SGD on the context-specific dataset. `full=false`
/// updates only the head group ("SurveilEdge" scheme); `full=true` trains
/// everything from scratch ("All Fine-tune" baseline).
#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn run_fine_tune(
    engine: &Engine,
    trainer: &super::TrainRunner,
    pretrained: &[Vec<f32>],
    pixels: &[f32],
    labels: &[i32],
    steps: usize,
    lr: f32,
    full: bool,
) -> crate::Result<FineTuneResult> {
    let t0 = std::time::Instant::now();
    let n = engine.manifest.edge_params.len();
    let (mut params, mask) = if full {
        // From-scratch: deterministic pseudo-random re-init of all params.
        let mut rng = crate::testkit::Rng::new(0xF17E_7A11);
        let params: Vec<Vec<f32>> = engine
            .manifest
            .edge_params
            .iter()
            .map(|s| {
                let fan_in: usize = s.shape[..s.shape.len().saturating_sub(1)]
                    .iter()
                    .product::<usize>()
                    .max(1);
                let std = (2.0 / fan_in as f64).sqrt() as f32;
                (0..s.numel())
                    .map(|_| if s.name.ends_with("_b") { 0.0 } else { rng.normal() as f32 * std })
                    .collect()
            })
            .collect();
        (params, vec![true; n])
    } else {
        (pretrained.to_vec(), MomentumSgd::head_only_mask(n, engine.manifest.edge_head_group))
    };

    let mut opt = MomentumSgd::new(&engine.manifest.edge_params, lr, mask);
    let batch = trainer.batch;
    let px_per = trainer.img * trainer.img * 3;
    let total = labels.len();
    anyhow::ensure!(total >= batch, "fine-tune dataset smaller than batch ({total} < {batch})");
    anyhow::ensure!(pixels.len() == total * px_per, "pixels/labels mismatch");

    let mut losses = Vec::with_capacity(steps);
    let mut accs = Vec::with_capacity(steps);
    let mut rng = crate::testkit::Rng::new(0x7EA1_5EED);
    let mut bpix = vec![0.0f32; batch * px_per];
    let mut blab = vec![0i32; batch];
    for _ in 0..steps {
        for j in 0..batch {
            let k = rng.range_usize(0, total);
            bpix[j * px_per..(j + 1) * px_per].copy_from_slice(&pixels[k * px_per..(k + 1) * px_per]);
            blab[j] = labels[k];
        }
        let out = trainer.grad_step(&params, &bpix, &blab)?;
        losses.push(out.loss);
        accs.push(out.acc);
        opt.step(&mut params, &out.grads);
    }
    Ok(FineTuneResult { params, losses, accs, train_secs: t0.elapsed().as_secs_f64() })
}

/// Reference-mode worker (default build, no `pjrt` feature): serves every
/// request through [`super::reference::ReferenceClassifier`]. Deterministic
/// and artifact-free — fine-tuning here *selects the query class* from the
/// labeled dataset (majority template vote over the positives) and encodes
/// it as the deployed "weights", which is exactly the piece of information
/// the real CQ-specific CNN's fine-tuned head carries.
#[cfg(not(feature = "pjrt"))]
fn reference_worker_main(
    artifact_dir: PathBuf,
    edge_ids: Vec<u32>,
    rx: Receiver<Request>,
    ready: SyncSender<crate::Result<()>>,
) {
    use std::time::Instant;

    use super::reference::{decode_query_params, ReferenceClassifier};
    use crate::types::ClassId;

    // Reference mode needs nothing from disk; the artifact dir is accepted
    // for signature compatibility with the PJRT worker.
    let _ = artifact_dir;
    let clf = ReferenceClassifier::new(32);
    // Per-edge deployment: the CQ the edge model was tuned for, or `None`
    // while the generic (query-less) pretrained weights are in place.
    let mut edges: HashMap<u32, Option<ClassId>> = HashMap::new();
    for id in &edge_ids {
        edges.insert(*id, None);
    }
    let mut snap = ServiceSnapshot::default();
    let _ = ready.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::EdgeInfer { edge_id, pixels, reply } => {
                let t0 = Instant::now();
                let r = match edges.get(&edge_id) {
                    // Fine-tuned: score against the deployed query class.
                    Some(Some(query)) => clf.edge_probs(&pixels, *query),
                    // Generic pretrained weights carry no query head yet:
                    // answer an uninformative 0.5 regardless of the query.
                    Some(None) => Ok(vec![0.5, 0.5]),
                    None => Err(anyhow::anyhow!("unknown edge {edge_id}")),
                };
                snap.edge_infer.record(t0.elapsed().as_secs_f64());
                let _ = reply.send(r);
            }
            Request::CloudInfer { pixels, reply } => {
                let t0 = Instant::now();
                let r = clf.cloud_probs(&pixels);
                snap.cloud_infer.record(t0.elapsed().as_secs_f64());
                let _ = reply.send(r);
            }
            Request::DeployEdge { edge_id, params, reply } => {
                edges.insert(edge_id, decode_query_params(&params));
                let _ = reply.send(Ok(()));
            }
            Request::FineTune { pixels, labels, steps, lr, full, reply } => {
                let t0 = Instant::now();
                let r = reference_fine_tune(&clf, &pixels, &labels, steps, lr, full, t0);
                snap.train.record(t0.elapsed().as_secs_f64());
                let _ = reply.send(r);
            }
            Request::FrameDiff { prev, cur, nxt, reply } => {
                let t0 = Instant::now();
                let r = reference_framediff(&prev, &cur, &nxt);
                snap.framediff.record(t0.elapsed().as_secs_f64());
                let _ = reply.send(r);
            }
            Request::Stats { reply } => {
                let _ = reply.send(snap.clone());
            }
            Request::Shutdown => break,
        }
    }
}

/// Reference-mode fine-tune: recover the query class from the labeled set
/// (majority template vote over positives), measure the resulting
/// classifier's accuracy on the set, and synthesise a convergence curve of
/// `steps` points toward it. Returned params encode the query class for
/// [`super::reference::decode_query_params`].
#[cfg(not(feature = "pjrt"))]
fn reference_fine_tune(
    clf: &super::reference::ReferenceClassifier,
    pixels: &[f32],
    labels: &[i32],
    steps: usize,
    lr: f32,
    full: bool,
    t0: std::time::Instant,
) -> crate::Result<FineTuneResult> {
    use super::reference::encode_query_params;

    anyhow::ensure!(!labels.is_empty(), "fine-tune dataset is empty");
    let px_per = clf.img() * clf.img() * 3;
    anyhow::ensure!(
        pixels.len() == labels.len() * px_per,
        "pixels/labels mismatch ({} px for {} labels of {px_per} px)",
        pixels.len(),
        labels.len()
    );
    let query = clf
        .majority_class(pixels, labels)
        .ok_or_else(|| anyhow::anyhow!("fine-tune dataset has no positive examples"))?;

    // Measured accuracy of the tuned reference classifier on this set.
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let probs = clf.edge_probs(&pixels[i * px_per..(i + 1) * px_per], query)?;
        let pred = (probs[1] >= 0.5) as i32;
        correct += (pred == label) as usize;
    }
    let final_acc = correct as f32 / labels.len() as f32;

    // Deterministic convergence curve: from-scratch training starts higher
    // and converges slower per-step than head-group fine-tuning, mirroring
    // the paper's Fig. 5 contrast.
    let (l0, rate) = if full { (2.08f32, lr * 20.0) } else { (0.69f32, lr * 40.0) };
    let floor = 0.05f32;
    let mut losses = Vec::with_capacity(steps);
    let mut accs = Vec::with_capacity(steps);
    for k in 0..steps {
        let decay = (-(rate * (k + 1) as f32)).exp();
        losses.push(floor + (l0 - floor) * decay);
        accs.push(final_acc - (final_acc - 0.5).max(0.0) * decay);
    }
    Ok(FineTuneResult {
        params: encode_query_params(query),
        losses,
        accs,
        train_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Reference-mode frame difference: the native dense stage
/// ([`crate::detect::framediff::framediff_native`]) at the detection
/// threshold, with the frame shape recovered from the calibrated 4:3
/// deployments (96×128 by default).
#[cfg(not(feature = "pjrt"))]
fn reference_framediff(prev: &[f32], cur: &[f32], nxt: &[f32]) -> crate::Result<Vec<u8>> {
    use crate::detect::framediff::framediff_native;
    use crate::types::Image;

    anyhow::ensure!(
        prev.len() == cur.len() && nxt.len() == cur.len() && cur.len() % 3 == 0,
        "frame triplet size mismatch"
    );
    let hw = cur.len() / 3;
    let h = ((hw as f64) * 0.75).sqrt().round() as usize;
    // Accept only exact 4:3 shapes: a near-miss that happens to divide
    // evenly must not silently produce a wrongly-shaped mask.
    anyhow::ensure!(
        h > 0 && hw % h == 0 && (hw / h) * 3 == h * 4,
        "cannot infer a 4:3 frame shape from {} pixels (reference mode only \
         supports the calibrated 4:3 deployments); build with --features pjrt \
         for manifest-driven frame shapes",
        hw
    );
    let w = hw / h;
    let as_img = |data: &[f32]| Image { h, w, data: data.to_vec() };
    Ok(framediff_native(&as_img(prev), &as_img(cur), &as_img(nxt), 0.1))
}

#[cfg(test)]
mod tests {
    // PJRT service tests require artifacts; they live in
    // rust/tests/pipeline_integration.rs so `cargo test --lib` stays fast.
    // Reference-mode behaviour is covered here (default build only).
    #![allow(unused_imports)]
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn reference_service_end_to_end() {
        use crate::harness::finetune_corpus;
        use crate::types::ClassId;

        let svc = InferenceService::spawn("artifacts".into(), vec![1, 2]).expect("spawn");
        let h = svc.handle.clone();

        // Cloud + edge inference on a rendered corpus crop.
        let (pixels, labels) = finetune_corpus(ClassId::Moped, 64, 7);
        let crop = pixels[..32 * 32 * 3].to_vec();
        let cloud = h.cloud_infer(crop.clone()).unwrap();
        assert_eq!(cloud.len(), 8);
        assert!((cloud.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        let edge = h.edge_infer(1, crop.clone()).unwrap();
        assert_eq!(edge.len(), 2);
        assert!((edge.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(h.edge_infer(99, crop.clone()).is_err(), "unknown edge must error");

        // Fine-tune recovers the query and deploying it sharpens answers.
        let ft = h.fine_tune(pixels.clone(), labels.clone(), 12, 0.005, false).unwrap();
        assert_eq!(ft.losses.len(), 12);
        assert!(ft.losses.windows(2).all(|w| w[1] <= w[0]), "losses must decrease");
        h.deploy_edge(1, ft.params.clone()).unwrap();
        let after = h.edge_infer(1, crop.clone()).unwrap();
        assert!((after[1] - edge[1]).abs() > 1e-6, "deploy must change the answer");

        // Frame diff on the default 96x128 frames.
        let n = 96 * 128 * 3;
        let prev = vec![0.2f32; n];
        let mut cur = vec![0.2f32; n];
        let mut nxt = vec![0.2f32; n];
        for i in 0..600 {
            cur[10_000 + i] = 0.9;
            nxt[20_000 + i] = 0.9;
        }
        let mask = h.framediff(prev, cur, nxt).unwrap();
        assert_eq!(mask.len(), 96 * 128);
        assert!(mask.iter().any(|&m| m == 1));

        let stats = h.stats().unwrap();
        assert!(stats.edge_infer.calls >= 3);
        assert!(stats.cloud_infer.calls >= 1);
        assert!(stats.framediff.calls >= 1);
    }
}
