//! Micro-batching executor: aggregates single-crop classify requests into
//! batched PJRT calls (the b8 artifacts), vLLM-router-style.
//!
//! Policy: collect up to `max_batch` requests, or whatever has arrived
//! when `max_wait` expires after the first request of a window; pad the
//! final partial batch with zeros and discard padded outputs. The paper's
//! cloud node serves many edges concurrently, which is exactly the arrival
//! pattern batching exploits; `bench_micro` quantifies when it pays off on
//! this host (small CNNs on CPU can prefer b1 — a recorded §Perf finding).

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::time::{Duration, Instant};

use super::ModelRunner;

/// One queued classification request.
pub struct BatchRequest {
    pub pixels: Vec<f32>,
    pub reply: SyncSender<crate::Result<Vec<f32>>>,
}

/// Queue statistics for observability.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub full_batches: u64,
}

impl BatcherStats {
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches as f64 * max_batch as f64)
    }
}

/// The batching loop. Owns a batched [`ModelRunner`] (thread-bound, so
/// this runs inside the inference-service thread or any single thread)
/// and a request receiver. Call [`MicroBatcher::pump`] to process one
/// batch window; loop it for a dedicated executor.
pub struct MicroBatcher {
    model: ModelRunner,
    rx: Receiver<BatchRequest>,
    pub max_wait: Duration,
    stats: BatcherStats,
    px_per_item: usize,
}

/// Sending side handle.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<BatchRequest>,
}

impl BatcherHandle {
    /// Enqueue a crop and wait for its probability row.
    pub fn infer(&self, pixels: Vec<f32>) -> crate::Result<Vec<f32>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(BatchRequest { pixels, reply })
            .map_err(|_| anyhow::anyhow!("batcher is down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("batcher dropped reply"))?
    }
}

impl MicroBatcher {
    /// Build around a model compiled at batch size `model.batch`.
    pub fn new(model: ModelRunner, queue_cap: usize, max_wait: Duration) -> (MicroBatcher, BatcherHandle) {
        let (tx, rx) = sync_channel(queue_cap.max(1));
        let px_per_item = model.img * model.img * 3;
        (
            MicroBatcher { model, rx, max_wait, stats: BatcherStats::default(), px_per_item },
            BatcherHandle { tx },
        )
    }

    pub fn stats(&self) -> BatcherStats {
        self.stats
    }

    /// Process one batch window. Blocks up to `idle_wait` for the first
    /// request; returns false if the channel closed (time to stop) and
    /// true otherwise (a batch may or may not have been executed).
    pub fn pump(&mut self, idle_wait: Duration) -> bool {
        let first = match self.rx.recv_timeout(idle_wait) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => return true,
            Err(RecvTimeoutError::Disconnected) => return false,
        };
        let max_batch = self.model.batch;
        let mut window = vec![first];
        let deadline = Instant::now() + self.max_wait;
        while window.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => window.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        self.execute(window);
        true
    }

    fn execute(&mut self, window: Vec<BatchRequest>) {
        let max_batch = self.model.batch;
        let n = window.len();
        self.stats.requests += n as u64;
        self.stats.batches += 1;
        if n == max_batch {
            self.stats.full_batches += 1;
        }
        // Validate sizes first so one bad request fails alone, not the batch.
        let mut good: Vec<(usize, &BatchRequest)> = Vec::with_capacity(n);
        for (i, r) in window.iter().enumerate() {
            if r.pixels.len() == self.px_per_item {
                good.push((i, r));
            } else {
                let _ = r.reply.send(Err(anyhow::anyhow!(
                    "bad crop size {} (want {})",
                    r.pixels.len(),
                    self.px_per_item
                )));
            }
        }
        if good.is_empty() {
            return;
        }
        let mut pixels = vec![0.0f32; max_batch * self.px_per_item];
        for (slot, (_, r)) in good.iter().enumerate() {
            pixels[slot * self.px_per_item..(slot + 1) * self.px_per_item]
                .copy_from_slice(&r.pixels);
        }
        match self.model.infer(&pixels) {
            Ok(rows) => {
                for (slot, (_, r)) in good.iter().enumerate() {
                    let _ = r.reply.send(Ok(rows[slot].clone()));
                }
            }
            Err(e) => {
                for (_, r) in &good {
                    let _ = r.reply.send(Err(anyhow::anyhow!("batched infer failed: {e}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_fill_ratio() {
        let s = BatcherStats { requests: 12, batches: 3, full_batches: 1 };
        assert!((s.mean_batch_fill(8) - 0.5).abs() < 1e-12);
        assert_eq!(BatcherStats::default().mean_batch_fill(8), 0.0);
    }

    // Behavioural tests (padding, partial windows, error isolation) need a
    // compiled model; they live in rust/tests/runtime_integration.rs.
}
