//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! (the offline vendor set has no `serde_json`). Supports objects, arrays,
//! strings (with \uXXXX and standard escapes), numbers, booleans, null.

use std::collections::HashMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> crate::Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["artifacts", "edge_infer_b1", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}, found {:?}",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} at byte {}, got {other:?}", self.pos),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] at byte {}, got {other:?}", self.pos),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        anyhow::bail!("dangling escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            anyhow::ensure!(self.pos + 4 <= self.bytes.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => anyhow::bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // Re-sync on UTF-8 boundaries by scanning raw bytes.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.at(&["d", "e"]), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ☂\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☂"));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(HashMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn as_usize_strictness() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).expect("manifest parses");
            assert!(j.get("artifacts").is_some());
            assert!(j.get("edge_params").unwrap().as_arr().unwrap().len() > 5);
        }
    }
}
