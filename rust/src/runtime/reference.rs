//! Pure-Rust reference classifier — the default-build stand-in for the
//! PJRT-executed CNNs.
//!
//! The synthetic substrate renders every object from an analytic per-class
//! shape specification ([`crate::video::sprite`]), so a shape-template
//! matcher is a faithful (and fully deterministic) reference
//! implementation of "a CNN that recognises these classes": extract the
//! crop's foreground mask, compare it against each class's canonical
//! silhouette by intersection-over-union, and softmax the scores. The
//! [`super::service::InferenceService`] serves this classifier when the
//! crate is built without the `pjrt` feature, which keeps
//! `surveiledge offline` and the examples runnable offline with no
//! artifacts and no XLA runtime.
//!
//! The CQ-specific "fine-tuned weights" of reference mode are just the
//! query class, encoded by [`encode_query_params`] — the one piece of
//! information the real fine-tuned head carries that the generic
//! pretrained weights do not.

use crate::types::{ClassId, NUM_CLASSES};
use crate::video::sprite::{render_sprite, SpriteParams};

/// Foreground threshold: a pixel belongs to the object when any channel
/// deviates from the estimated background by more than this.
const FG_THRESHOLD: f32 = 0.12;

/// Softmax sharpness over the IoU scores (calibrated so clean sprites get
/// confident argmax probabilities while ambiguous crops stay soft).
const SHARPNESS: f64 = 12.0;

/// Template-matching classifier over the 8 object classes.
pub struct ReferenceClassifier {
    img: usize,
    /// Per-class canonical silhouette at `img`×`img` (rot 0, no jitter).
    templates: Vec<Vec<bool>>,
}

impl ReferenceClassifier {
    /// Build the classifier at the CNN input resolution (32 in the bundle).
    pub fn new(img: usize) -> ReferenceClassifier {
        let templates = (0..NUM_CLASSES)
            .map(|i| {
                let sprite = render_sprite(&SpriteParams {
                    cls: ClassId::from_index(i).expect("class index"),
                    size: img,
                    base: [1.0, 1.0, 1.0],
                    accent: [1.0, 1.0, 1.0],
                    bg: [0.0, 0.0, 0.0],
                    rot: 0.0,
                    jx: 0.0,
                    jy: 0.0,
                    noise: 0.0,
                    seed: 0,
                });
                // Layers are white or the (dark, but non-zero) wheel colour
                // on a black background: any lit channel marks foreground.
                sprite
                    .data
                    .chunks_exact(3)
                    .map(|px| px[0].max(px[1]).max(px[2]) > 0.05)
                    .collect()
            })
            .collect();
        ReferenceClassifier { img, templates }
    }

    /// CNN input resolution this classifier was built for.
    pub fn img(&self) -> usize {
        self.img
    }

    /// Foreground mask of a crop: estimate the background colour from the
    /// border pixels, then threshold the per-pixel deviation.
    pub fn foreground_mask(&self, pixels: &[f32]) -> Vec<bool> {
        let s = self.img;
        let mut bg = [0.0f32; 3];
        let mut n = 0usize;
        for y in 0..s {
            for x in 0..s {
                if y == 0 || y == s - 1 || x == 0 || x == s - 1 {
                    let i = (y * s + x) * 3;
                    bg[0] += pixels[i];
                    bg[1] += pixels[i + 1];
                    bg[2] += pixels[i + 2];
                    n += 1;
                }
            }
        }
        for c in bg.iter_mut() {
            *c /= n.max(1) as f32;
        }
        pixels
            .chunks_exact(3)
            .map(|px| {
                (px[0] - bg[0])
                    .abs()
                    .max((px[1] - bg[1]).abs())
                    .max((px[2] - bg[2]).abs())
                    > FG_THRESHOLD
            })
            .collect()
    }

    /// Per-class IoU between the crop's foreground mask and the canonical
    /// class silhouettes.
    pub fn scores(&self, pixels: &[f32]) -> crate::Result<[f64; NUM_CLASSES]> {
        anyhow::ensure!(
            pixels.len() == self.img * self.img * 3,
            "reference classifier: got {} px, want {}x{}x3",
            pixels.len(),
            self.img,
            self.img
        );
        let mask = self.foreground_mask(pixels);
        let mut out = [0.0f64; NUM_CLASSES];
        for (ci, tmpl) in self.templates.iter().enumerate() {
            let mut inter = 0usize;
            let mut union = 0usize;
            for (a, b) in mask.iter().zip(tmpl.iter()) {
                inter += (*a && *b) as usize;
                union += (*a || *b) as usize;
            }
            out[ci] = if union == 0 { 0.0 } else { inter as f64 / union as f64 };
        }
        Ok(out)
    }

    /// 8-class probabilities (the cloud CNN stand-in): softmax over IoUs.
    pub fn cloud_probs(&self, pixels: &[f32]) -> crate::Result<Vec<f32>> {
        let scores = self.scores(pixels)?;
        let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| ((s - max) * SHARPNESS).exp()).collect();
        let total: f64 = exps.iter().sum();
        Ok(exps.iter().map(|e| (e / total) as f32).collect())
    }

    /// Binary query confidence `[p_not_query, p_query]` (the CQ-specific
    /// edge CNN stand-in). The query class is the "fine-tuned head": an
    /// edge that has not been fine-tuned yet has no query to score against
    /// (the service answers an uninformative 0.5 for it instead).
    pub fn edge_probs(&self, pixels: &[f32], query: ClassId) -> crate::Result<Vec<f32>> {
        let scores = self.scores(pixels)?;
        let s_q = scores[query.index()];
        let s_other = scores
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != query.index())
            .map(|(_, s)| *s)
            .fold(0.0f64, f64::max);
        let f = (s_q / (s_q + s_other + 1e-6)) as f32;
        Ok(vec![1.0 - f, f])
    }

    /// Majority class among the positively-labeled crops of a fine-tune
    /// dataset — how reference mode recovers the query class.
    pub fn majority_class(&self, pixels: &[f32], labels: &[i32]) -> Option<ClassId> {
        let px_per = self.img * self.img * 3;
        let mut counts = [0usize; NUM_CLASSES];
        for (i, &label) in labels.iter().enumerate() {
            if label != 1 {
                continue;
            }
            let crop = pixels.get(i * px_per..(i + 1) * px_per)?;
            if let Ok(scores) = self.scores(crop) {
                let mut best = 0usize;
                for c in 1..NUM_CLASSES {
                    if scores[c] > scores[best] {
                        best = c;
                    }
                }
                counts[best] += 1;
            }
        }
        let (best, n) = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, n)| **n)
            .map(|(i, n)| (i, *n))?;
        if n == 0 {
            None
        } else {
            ClassId::from_index(best)
        }
    }
}

/// Encode a query class as reference-mode "deployed weights".
pub fn encode_query_params(query: ClassId) -> Vec<Vec<f32>> {
    vec![vec![query.index() as f32]]
}

/// Decode reference-mode deployed weights back to the query class; `None`
/// for any other weight layout (treated as the generic pretrained weights).
pub fn decode_query_params(params: &[Vec<f32>]) -> Option<ClassId> {
    if params.len() == 1 && params[0].len() == 1 {
        let v = params[0][0];
        if v.is_finite() && v >= 0.0 {
            ClassId::from_index(v.round() as usize)
        } else {
            None
        }
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_crop(cls: ClassId, seed: u32) -> Vec<f32> {
        render_sprite(&SpriteParams {
            cls,
            size: 24,
            base: [0.8, 0.25, 0.2],
            accent: [0.2, 0.35, 0.8],
            bg: [0.45, 0.47, 0.44],
            rot: 0.0,
            jx: 0.0,
            jy: 0.0,
            noise: 0.03,
            seed,
        })
        .resize(32, 32)
        .data
    }

    #[test]
    fn templates_recognise_their_classes() {
        let clf = ReferenceClassifier::new(32);
        let mut correct = 0;
        for i in 0..NUM_CLASSES {
            let cls = ClassId::from_index(i).unwrap();
            let probs = clf.cloud_probs(&demo_crop(cls, 100 + i as u32)).unwrap();
            let argmax = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += (argmax == i) as usize;
        }
        assert!(correct >= 6, "reference classifier got only {correct}/8 clean sprites");
    }

    #[test]
    fn cloud_probs_are_a_distribution() {
        let clf = ReferenceClassifier::new(32);
        let probs = clf.cloud_probs(&demo_crop(ClassId::Bus, 7)).unwrap();
        assert_eq!(probs.len(), NUM_CLASSES);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn edge_probs_separate_query_from_rest() {
        let clf = ReferenceClassifier::new(32);
        let pos = clf.edge_probs(&demo_crop(ClassId::Moped, 9), ClassId::Moped).unwrap();
        let neg = clf.edge_probs(&demo_crop(ClassId::Car, 11), ClassId::Moped).unwrap();
        assert!(pos[1] > 0.55, "query sprite confidence {}", pos[1]);
        assert!(neg[1] < 0.45, "non-query sprite confidence {}", neg[1]);
        assert!((pos[0] + pos[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn wrong_input_size_is_an_error() {
        let clf = ReferenceClassifier::new(32);
        assert!(clf.cloud_probs(&[0.0; 10]).is_err());
        assert!(clf.edge_probs(&[0.0; 10], ClassId::Car).is_err());
    }

    #[test]
    fn majority_vote_recovers_query() {
        let clf = ReferenceClassifier::new(32);
        let mut pixels = Vec::new();
        let mut labels = Vec::new();
        for i in 0..8u32 {
            let positive = i % 2 == 0;
            let cls = if positive { ClassId::Person } else { ClassId::Truck };
            pixels.extend_from_slice(&demo_crop(cls, 40 + i));
            labels.push(positive as i32);
        }
        assert_eq!(clf.majority_class(&pixels, &labels), Some(ClassId::Person));
        assert_eq!(clf.majority_class(&[], &[]), None);
    }

    #[test]
    fn query_params_roundtrip() {
        for i in 0..NUM_CLASSES {
            let cls = ClassId::from_index(i).unwrap();
            assert_eq!(decode_query_params(&encode_query_params(cls)), Some(cls));
        }
        assert_eq!(decode_query_params(&[]), None);
        assert_eq!(decode_query_params(&[vec![1.0, 2.0]]), None);
        assert_eq!(decode_query_params(&[vec![-1.0]]), None);
        assert_eq!(decode_query_params(&[vec![f32::NAN]]), None);
        assert_eq!(decode_query_params(&[vec![99.0]]), None);
    }
}
