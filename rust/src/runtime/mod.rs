//! PJRT runtime: loads the AOT HLO artifacts and runs them on the request
//! path (the only place compute happens at serving time — Python is
//! build-time only).
//!
//! Execution pattern (gated behind the `pjrt` cargo feature): parse the HLO
//! text module (`HloModuleProto::from_text_file`), wrap it as a computation
//! (`XlaComputation::from_proto`), compile it once on the PJRT CPU client
//! (`PjRtClient::cpu` + `compile`), then `execute_b` per request. One
//! compiled executable per model variant; CNN weights are **runtime
//! arguments**, uploaded once as device buffers and reused across calls, so
//! deploying fine-tuned weights is a buffer swap, not a recompile.
//!
//! Without the `pjrt` feature, the artifact bookkeeping here ([`Manifest`],
//! blobs, [`MomentumSgd`]) still compiles, and [`service`] serves requests
//! through the pure-Rust [`reference`] classifier instead.

pub mod json;
pub mod reference;
pub mod service;
#[cfg(feature = "pjrt")]
pub mod batcher;

use std::collections::HashMap;
use std::io::Read;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;
#[cfg(feature = "pjrt")]
use std::time::Instant;

use json::Json;

/// Shape + name of one model parameter.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub img: usize,
    pub frame_h: usize,
    pub frame_w: usize,
    pub classes: Vec<String>,
    pub query_cls: usize,
    pub edge_train_batch: usize,
    pub edge_params: Vec<ParamSpec>,
    pub cloud_params: Vec<ParamSpec>,
    /// Number of trailing edge params in the fine-tune head group.
    pub edge_head_group: usize,
    /// artifact name -> file name
    pub artifacts: HashMap<String, String>,
    pub weights: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("manifest.json missing in {dir:?} (run `make artifacts`): {e}"))?;
        let j = Json::parse(&text)?;
        let params = |key: &str| -> crate::Result<Vec<ParamSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("manifest: missing {key}"))?
                .iter()
                .map(|e| {
                    let name = e.get("name").and_then(Json::as_str).unwrap_or("").to_string();
                    let shape: Vec<usize> = e
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default();
                    anyhow::ensure!(!name.is_empty() && !shape.is_empty(), "bad param entry");
                    Ok(ParamSpec { name, shape })
                })
                .collect()
        };
        let frame = j.get("frame").and_then(Json::as_arr).ok_or_else(|| anyhow::anyhow!("frame"))?;
        let mut artifacts = HashMap::new();
        for (k, v) in j.get("artifacts").and_then(Json::as_obj).into_iter().flatten() {
            if let Some(f) = v.get("file").and_then(Json::as_str) {
                artifacts.insert(k.clone(), f.to_string());
            }
        }
        let mut weights = HashMap::new();
        for (k, v) in j.get("weights").and_then(Json::as_obj).into_iter().flatten() {
            if let Some(f) = v.as_str() {
                weights.insert(k.clone(), f.to_string());
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            img: j.get("img").and_then(Json::as_usize).unwrap_or(32),
            frame_h: frame[0].as_usize().unwrap_or(96),
            frame_w: frame[1].as_usize().unwrap_or(128),
            classes: j
                .get("classes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
                .unwrap_or_default(),
            query_cls: j.get("query_cls").and_then(Json::as_usize).unwrap_or(3),
            edge_train_batch: j.get("edge_train_batch").and_then(Json::as_usize).unwrap_or(32),
            edge_params: params("edge_params")?,
            cloud_params: params("cloud_params")?,
            edge_head_group: j.get("edge_head_group").and_then(Json::as_usize).unwrap_or(6),
            artifacts,
            weights,
        })
    }

    pub fn artifact_path(&self, name: &str) -> crate::Result<PathBuf> {
        self.artifacts
            .get(name)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn weight_path(&self, name: &str) -> crate::Result<PathBuf> {
        self.weights
            .get(name)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("weights {name:?} not in manifest"))
    }
}

/// Load a raw f32 blob (8-byte little-endian length header + payload),
/// the format `aot.py::write_blob` emits.
pub fn read_blob(path: &Path) -> crate::Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)?;
    let mut header = [0u8; 8];
    f.read_exact(&mut header)?;
    let n = u64::from_le_bytes(header) as usize;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() == n * 4, "{path:?}: header {} vs payload {}", n * 4, bytes.len());
    let mut out = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(out)
}

/// Write a blob in the same format (used to persist fine-tuned weights).
pub fn write_blob(path: &Path, data: &[f32]) -> crate::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    f.write_all(&(data.len() as u64).to_le_bytes())?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Slice a flat weight blob into per-parameter vectors per the manifest.
pub fn split_params(flat: &[f32], specs: &[ParamSpec]) -> crate::Result<Vec<Vec<f32>>> {
    let total: usize = specs.iter().map(ParamSpec::numel).sum();
    anyhow::ensure!(flat.len() == total, "weight blob {} != manifest {}", flat.len(), total);
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        out.push(flat[off..off + s.numel()].to_vec());
        off += s.numel();
    }
    Ok(out)
}

/// Concatenate per-parameter vectors back into a flat blob.
pub fn join_params(params: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(params.iter().map(Vec::len).sum());
    for p in params {
        out.extend_from_slice(p);
    }
    out
}

/// Per-call service measurement (drives calibration + §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    pub calls: u64,
    pub total_secs: f64,
    pub max_secs: f64,
}

impl ServiceStats {
    fn record(&mut self, secs: f64) {
        self.calls += 1;
        self.total_secs += secs;
        self.max_secs = self.max_secs.max(secs);
    }

    pub fn mean(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_secs / self.calls as f64
        }
    }
}

/// A compiled model with its weights resident on device.
#[cfg(feature = "pjrt")]
pub struct ModelRunner {
    exe: xla::PjRtLoadedExecutable,
    param_buffers: Vec<xla::PjRtBuffer>,
    specs: Vec<ParamSpec>,
    pub batch: usize,
    pub img: usize,
    pub out_classes: usize,
    stats: Mutex<ServiceStats>,
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl ModelRunner {
    /// Probability output for a batch of crops. `pixels` is HWC f32 of
    /// exactly `batch * img * img * 3` elements. Returns `batch` rows of
    /// `out_classes` probabilities.
    pub fn infer(&self, pixels: &[f32]) -> crate::Result<Vec<Vec<f32>>> {
        let want = self.batch * self.img * self.img * 3;
        anyhow::ensure!(pixels.len() == want, "infer: got {} px, want {want}", pixels.len());
        let t0 = Instant::now();
        let x = self
            .client
            .buffer_from_host_buffer(pixels, &[self.batch, self.img, self.img, 3], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.param_buffers.iter().collect();
        args.push(&x);
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let probs = lit.to_tuple1()?.to_vec::<f32>()?;
        anyhow::ensure!(probs.len() == self.batch * self.out_classes, "bad output size");
        self.stats.lock().unwrap().record(t0.elapsed().as_secs_f64());
        Ok(probs.chunks(self.out_classes).map(|c| c.to_vec()).collect())
    }

    /// Swap in new weights (fine-tune deployment): re-uploads buffers.
    pub fn set_params(&mut self, params: &[Vec<f32>]) -> crate::Result<()> {
        self.param_buffers = upload_params(&self.client, &self.specs, params)?;
        Ok(())
    }

    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }
}

#[cfg(feature = "pjrt")]
fn upload_params(
    client: &xla::PjRtClient,
    specs: &[ParamSpec],
    params: &[Vec<f32>],
) -> crate::Result<Vec<xla::PjRtBuffer>> {
    anyhow::ensure!(specs.len() == params.len(), "param count mismatch");
    specs
        .iter()
        .zip(params.iter())
        .map(|(s, p)| {
            anyhow::ensure!(p.len() == s.numel(), "{}: {} vs {:?}", s.name, p.len(), s.shape);
            Ok(client.buffer_from_host_buffer(p, &s.shape, None)?)
        })
        .collect()
}

/// One gradient step's outputs.
#[derive(Clone, Debug)]
pub struct GradOutput {
    pub grads: Vec<Vec<f32>>,
    pub loss: f32,
    pub acc: f32,
}

/// The edge_train executable: (params.., x, y) -> (grads.., loss, acc).
#[cfg(feature = "pjrt")]
pub struct TrainRunner {
    exe: xla::PjRtLoadedExecutable,
    specs: Vec<ParamSpec>,
    pub batch: usize,
    pub img: usize,
    stats: Mutex<ServiceStats>,
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl TrainRunner {
    pub fn grad_step(
        &self,
        params: &[Vec<f32>],
        pixels: &[f32],
        labels: &[i32],
    ) -> crate::Result<GradOutput> {
        anyhow::ensure!(labels.len() == self.batch, "labels {} != batch {}", labels.len(), self.batch);
        anyhow::ensure!(pixels.len() == self.batch * self.img * self.img * 3, "bad pixel count");
        let t0 = Instant::now();
        let mut args = upload_params(&self.client, &self.specs, params)?;
        args.push(self.client.buffer_from_host_buffer(
            pixels,
            &[self.batch, self.img, self.img, 3],
            None,
        )?);
        args.push(self.client.buffer_from_host_buffer(labels, &[self.batch], None)?);
        let result = self.exe.execute_b(&args)?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(outs.len() == self.specs.len() + 2, "train outputs {}", outs.len());
        let mut grads = Vec::with_capacity(self.specs.len());
        for (lit, spec) in outs.iter().zip(self.specs.iter()) {
            let g = lit.to_vec::<f32>()?;
            anyhow::ensure!(g.len() == spec.numel(), "grad size {}", spec.name);
            grads.push(g);
        }
        let loss = outs[self.specs.len()].to_vec::<f32>()?[0];
        let acc = outs[self.specs.len() + 1].to_vec::<f32>()?[0];
        self.stats.lock().unwrap().record(t0.elapsed().as_secs_f64());
        Ok(GradOutput { grads, loss, acc })
    }

    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }
}

/// The framediff executable: 3 frames -> binary mask.
#[cfg(feature = "pjrt")]
pub struct FrameDiffRunner {
    exe: xla::PjRtLoadedExecutable,
    pub h: usize,
    pub w: usize,
    stats: Mutex<ServiceStats>,
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl FrameDiffRunner {
    pub fn mask(&self, prev: &[f32], cur: &[f32], nxt: &[f32]) -> crate::Result<Vec<u8>> {
        let want = self.h * self.w * 3;
        anyhow::ensure!(prev.len() == want && cur.len() == want && nxt.len() == want, "bad frame");
        let t0 = Instant::now();
        let dims = [1usize, self.h, self.w, 3];
        let args = [
            self.client.buffer_from_host_buffer(prev, &dims, None)?,
            self.client.buffer_from_host_buffer(cur, &dims, None)?,
            self.client.buffer_from_host_buffer(nxt, &dims, None)?,
        ];
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?.to_tuple1()?;
        let mask_f = lit.to_vec::<f32>()?;
        self.stats.lock().unwrap().record(t0.elapsed().as_secs_f64());
        Ok(mask_f.iter().map(|&v| (v > 0.5) as u8).collect())
    }

    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }
}

/// The engine: one PJRT CPU client + every compiled executable the
/// deployment needs.
#[cfg(feature = "pjrt")]
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn new(artifact_dir: &Path) -> crate::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { manifest, client })
    }

    fn compile(&self, artifact: &str) -> crate::Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.artifact_path(artifact)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Compile an edge/cloud inference model and upload its weights.
    pub fn model(
        &self,
        artifact: &str,
        specs: &[ParamSpec],
        params: &[Vec<f32>],
        batch: usize,
        out_classes: usize,
    ) -> crate::Result<ModelRunner> {
        let exe = self.compile(artifact)?;
        Ok(ModelRunner {
            exe,
            param_buffers: upload_params(&self.client, specs, params)?,
            specs: specs.to_vec(),
            batch,
            img: self.manifest.img,
            out_classes,
            stats: Mutex::new(ServiceStats::default()),
            client: self.client.clone(),
        })
    }

    /// Edge inference model at a given batch size with the given weights.
    pub fn edge_model(&self, batch: usize, params: &[Vec<f32>]) -> crate::Result<ModelRunner> {
        let specs = self.manifest.edge_params.clone();
        self.model(&format!("edge_infer_b{batch}"), &specs, params, batch, 2)
    }

    /// Cloud inference model (8-class) with the given weights.
    pub fn cloud_model(&self, batch: usize, params: &[Vec<f32>]) -> crate::Result<ModelRunner> {
        let specs = self.manifest.cloud_params.clone();
        let classes = self.manifest.classes.len().max(8);
        self.model(&format!("cloud_infer_b{batch}"), &specs, params, batch, classes)
    }

    pub fn trainer(&self) -> crate::Result<TrainRunner> {
        let exe = self.compile("edge_train")?;
        Ok(TrainRunner {
            exe,
            specs: self.manifest.edge_params.clone(),
            batch: self.manifest.edge_train_batch,
            img: self.manifest.img,
            stats: Mutex::new(ServiceStats::default()),
            client: self.client.clone(),
        })
    }

    pub fn framediff(&self) -> crate::Result<FrameDiffRunner> {
        let exe = self.compile("framediff")?;
        Ok(FrameDiffRunner {
            exe,
            h: self.manifest.frame_h,
            w: self.manifest.frame_w,
            stats: Mutex::new(ServiceStats::default()),
            client: self.client.clone(),
        })
    }

    /// Load the pretrained edge weights from the bundle.
    pub fn edge_pretrained(&self) -> crate::Result<Vec<Vec<f32>>> {
        let flat = read_blob(&self.manifest.weight_path("edge_pretrained")?)?;
        split_params(&flat, &self.manifest.edge_params)
    }

    /// Load the trained cloud weights from the bundle.
    pub fn cloud_trained(&self) -> crate::Result<Vec<Vec<f32>>> {
        let flat = read_blob(&self.manifest.weight_path("cloud_trained")?)?;
        split_params(&flat, &self.manifest.cloud_params)
    }
}

/// Momentum-SGD with a per-parameter update mask — the optimizer lives in
/// Rust (the train HLO returns raw grads) so the three Fig. 5 training
/// schemes share one artifact: "fine-tune" masks updates to the head
/// group, "all fine-tune" updates everything.
pub struct MomentumSgd {
    pub lr: f32,
    pub mu: f32,
    vel: Vec<Vec<f32>>,
    /// `mask[i]` = whether param i is updated.
    pub mask: Vec<bool>,
}

impl MomentumSgd {
    pub fn new(specs: &[ParamSpec], lr: f32, mask: Vec<bool>) -> MomentumSgd {
        assert_eq!(specs.len(), mask.len());
        MomentumSgd {
            lr,
            mu: 0.9,
            vel: specs.iter().map(|s| vec![0.0; s.numel()]).collect(),
            mask,
        }
    }

    /// Mask helper: update only the trailing `head_group` params.
    pub fn head_only_mask(n_params: usize, head_group: usize) -> Vec<bool> {
        (0..n_params).map(|i| i >= n_params.saturating_sub(head_group)).collect()
    }

    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        for i in 0..params.len() {
            if !self.mask[i] {
                continue;
            }
            let vel = &mut self.vel[i];
            let (p, g) = (&mut params[i], &grads[i]);
            for j in 0..p.len() {
                vel[j] = self.mu * vel[j] - self.lr * g[j];
                p[j] += vel[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_params_layout() {
        let specs = vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3] },
            ParamSpec { name: "b".into(), shape: vec![4] },
        ];
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let parts = split_params(&flat, &specs).unwrap();
        assert_eq!(parts[0], (0..6).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(parts[1], (6..10).map(|i| i as f32).collect::<Vec<_>>());
        assert!(split_params(&flat[..9], &specs).is_err());
        assert_eq!(join_params(&parts), flat);
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("surveiledge_test_blob");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data: Vec<f32> = vec![1.5, -2.25, 0.0, 3.75];
        write_blob(&path, &data).unwrap();
        assert_eq!(read_blob(&path).unwrap(), data);
    }

    #[test]
    fn momentum_masks_params() {
        let specs = vec![
            ParamSpec { name: "w".into(), shape: vec![2] },
            ParamSpec { name: "h".into(), shape: vec![2] },
        ];
        let mask = MomentumSgd::head_only_mask(2, 1);
        assert_eq!(mask, vec![false, true]);
        let mut opt = MomentumSgd::new(&specs, 0.1, mask);
        let mut params = vec![vec![1.0f32, 1.0], vec![1.0f32, 1.0]];
        let grads = vec![vec![1.0f32, 1.0], vec![1.0f32, 1.0]];
        opt.step(&mut params, &grads);
        assert_eq!(params[0], vec![1.0, 1.0], "masked param moved");
        assert!(params[1][0] < 1.0, "unmasked param did not move");
    }

    #[test]
    fn momentum_accumulates() {
        let specs = vec![ParamSpec { name: "w".into(), shape: vec![1] }];
        let mut opt = MomentumSgd::new(&specs, 0.1, vec![true]);
        let mut params = vec![vec![0.0f32]];
        let grads = vec![vec![1.0f32]];
        opt.step(&mut params, &grads);
        let d1 = -params[0][0];
        opt.step(&mut params, &grads);
        let d2 = -params[0][0] - d1;
        assert!(d2 > d1, "momentum should accelerate: {d1} vs {d2}");
    }

    #[test]
    fn head_only_mask_oversized_group() {
        // A head group larger than the param list must not underflow.
        assert_eq!(MomentumSgd::head_only_mask(2, 5), vec![true, true]);
    }

    #[test]
    fn service_stats_mean() {
        let mut s = ServiceStats::default();
        s.record(1.0);
        s.record(3.0);
        assert_eq!(s.calls, 2);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.max_secs, 3.0);
    }
}
