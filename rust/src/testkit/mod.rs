//! Minimal deterministic RNG + property-testing harness.
//!
//! The offline vendor set has no `rand` or `proptest`, so this module
//! provides what the rest of the crate needs: a SplitMix64/xoshiro-style
//! PRNG ([`Rng`]) and a tiny property-test runner ([`check`]) with seed
//! reporting on failure (re-run a failure by pinning `SURVEILEDGE_PROP_SEED`).

use std::env;

/// SplitMix64-seeded xorshift* PRNG — fast, deterministic, good enough for
/// workload generation and property tests (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 scramble so small seeds don't correlate streams.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Rng { state: (z ^ (z >> 31)) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Lognormal with log-mean `mu`, log-std `sigma`, shift `gamma`.
    pub fn lognormal3(&mut self, mu: f64, sigma: f64, gamma: f64) -> f64 {
        gamma + (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-camera / per-node RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// Number of cases per property (override with `SURVEILEDGE_PROP_CASES`).
pub fn prop_cases() -> usize {
    env::var("SURVEILEDGE_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Property-test runner: calls `prop(rng, case_index)`; on panic, reports
/// the failing master seed so the case can be replayed with
/// `SURVEILEDGE_PROP_SEED=<seed>`.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, mut prop: F) {
    let master: u64 = env::var("SURVEILEDGE_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_0001);
    let cases = prop_cases();
    for case in 0..cases {
        let seed = master.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with SURVEILEDGE_PROP_SEED={master}): {e:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_distribution() {
        let mut r = Rng::new(7);
        let w = [1.0, 3.0];
        let n = 20_000;
        let ones = (0..n).filter(|_| r.weighted(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_usize_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range_usize(3, 7);
            assert!((3..7).contains(&x));
        }
    }

    #[test]
    fn lognormal3_above_shift() {
        let mut r = Rng::new(10);
        for _ in 0..1000 {
            assert!(r.lognormal3(0.0, 1.0, 2.5) > 2.5);
        }
    }

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counting", |_, _| {
            count += 1;
        });
        assert_eq!(count, prop_cases());
    }
}
