//! Task scheduling + parameter adjustment (paper §IV-D).
//!
//! * [`allocate`] — eq. 7: route each detected object to the node with the
//!   least expected wait, `d = argmin_i Q_i·t_i` over edges and the Cloud.
//! * [`ThresholdController`] — eqs. 8–9: adapt the confidence band [β, α]
//!   from the observed classification latency vs the query interval `s`.
//!   When the system falls behind, the band narrows (fewer uploads); when
//!   it has headroom, the band widens (more cloud re-checks ⇒ accuracy).

use crate::obs::{node_label, Registry};
use crate::types::NodeId;

/// A routing-table snapshot for one candidate node.
#[derive(Clone, Copy, Debug)]
pub struct NodeLoad {
    pub node: NodeId,
    /// Queue length Q_i (tasks waiting, including in service).
    pub queue: usize,
    /// Estimated per-task inference latency t_i (seconds).
    pub t_infer: f64,
    /// Extra fixed cost of choosing this node (e.g. crop upload time to
    /// the Cloud). The paper ignores edge↔edge transmission but notes it
    /// is straightforward to model; we expose it and default it to 0.
    pub penalty: f64,
}

impl NodeLoad {
    /// Expected wait if the task is appended to this node's queue.
    pub fn cost(&self) -> f64 {
        self.queue as f64 * self.t_infer + self.penalty
    }
}

/// Eq. 7: pick the node with minimal `Q_i·t_i` (+penalty). Ties break
/// toward the *local* node (first entry) to avoid pointless transfers,
/// then toward lower node id for determinism.
pub fn allocate(candidates: &[NodeLoad]) -> Option<NodeId> {
    const EPS: f64 = 1e-12;
    let mut best: Option<(usize, &NodeLoad)> = None;
    for (i, c) in candidates.iter().enumerate() {
        let better = match best {
            None => true,
            Some((bi, b)) => {
                let (cb, cc) = (b.cost(), c.cost());
                // Strictly cheaper wins; on a tie the incumbent first entry
                // (the local node) is kept, otherwise the lower id wins.
                cc < cb - EPS || ((cc - cb).abs() <= EPS && bi != 0 && c.node < b.node)
            }
        };
        if better {
            best = Some((i, c));
        }
    }
    best.map(|(_, b)| b.node)
}

/// Scale every candidate's congestion-dependent cost (`Q_i·t_i` +
/// penalty) by a query deadline weight before eq. 7 runs. Weight > 1
/// (interactive) makes congested paths look worse than they are, so the
/// allocator flees to fast nodes sooner; weight < 1 (batch) tolerates
/// congestion and keeps traffic local. Weight 1 is exactly a no-op, so
/// query-less runs are untouched.
///
/// Implemented by scaling both `t_infer` and `penalty`: the *ordering*
/// over candidates at a fixed weight is unchanged (a uniform positive
/// scale preserves argmin), but the recorded queue-depth gauges and any
/// mixed-weight comparisons see the deadline pressure.
pub fn weight_penalties(candidates: &mut [NodeLoad], weight: f64) {
    if !(weight.is_finite() && weight > 0.0) || (weight - 1.0).abs() < 1e-12 {
        return;
    }
    for c in candidates.iter_mut() {
        c.t_infer *= weight;
        c.penalty *= weight;
    }
}

/// Record one eq. 7 allocation decision into a metric registry: a counter
/// per chosen destination and a queue-depth gauge per candidate node.
pub fn record_allocation(reg: &Registry, scheme: &str, dest: NodeId, candidates: &[NodeLoad]) {
    let dest_label = node_label(dest.0);
    reg.inc(
        "surveiledge_sched_alloc_total",
        &[("scheme", scheme), ("dest", dest_label.as_str())],
        1,
    );
    for c in candidates {
        let nl = node_label(c.node.0);
        reg.gauge_set(
            "surveiledge_sched_queue_depth",
            &[("scheme", scheme), ("node", nl.as_str())],
            c.queue as f64,
        );
    }
}

/// Record one candidate exclusion into a metric registry: a node that eq.
/// 7 never considered and why (`reason`: "circuit_open", "stale_hb",
/// ...). Overload control uses this when an open uplink breaker removes
/// the cloud from candidacy, composing with the stale-heartbeat exclusion
/// that simply never pushes dead nodes.
pub fn record_exclusion(reg: &Registry, scheme: &str, node: NodeId, reason: &str) {
    let nl = node_label(node.0);
    reg.inc(
        "surveiledge_sched_skipped_total",
        &[("scheme", scheme), ("node", nl.as_str()), ("reason", reason)],
        1,
    );
}

/// Configuration for the eq. 8–9 controller.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdConfig {
    /// γ₁ — step weight on the latency surplus (paper: γ₁ ∈ (0,1)).
    pub gamma1: f64,
    /// γ₂ — β as a fraction of (1-α) (paper: γ₂ ∈ (0,1), keeps the
    /// band average below 0.5, biasing toward recall).
    pub gamma2: f64,
    /// Query sampling interval `s` (seconds).
    pub interval: f64,
}

impl Default for ThresholdConfig {
    fn default() -> ThresholdConfig {
        ThresholdConfig { gamma1: 0.1, gamma2: 0.25, interval: 1.0 }
    }
}

/// The adaptive [β, α] confidence band.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdController {
    pub alpha: f64,
    pub beta: f64,
    cfg: ThresholdConfig,
}

impl ThresholdController {
    pub fn new(alpha0: f64, cfg: ThresholdConfig) -> ThresholdController {
        let alpha = alpha0.clamp(0.5, 1.0);
        ThresholdController { alpha, beta: cfg.gamma2 * (1.0 - alpha), cfg }
    }

    /// Paper's fixed-threshold baseline (SurveilEdge(fixed)): α=0.8, β=0.1.
    pub fn fixed() -> ThresholdController {
        ThresholdController {
            alpha: 0.8,
            beta: 0.1,
            cfg: ThresholdConfig { gamma1: 0.0, gamma2: 0.0, interval: 1.0 },
        }
    }

    /// Eq. 8–9 update from the current load signal:
    /// * `queue` — outstanding tasks on the deciding node (l_d),
    /// * `t_infer` — its per-task latency estimate (t_d).
    ///
    /// `α_new = max(min(α_old − γ₁(l_d·t_d − s), 1), 0.5)`;
    /// `β_new = γ₂(1 − α_new)`.
    ///
    /// When `l_d·t_d > s` (overloaded) α *drops* toward 0.5 and β drops
    /// with it, narrowing the upload band; with headroom α rises toward 1
    /// and the band widens.
    pub fn update(&mut self, queue: usize, t_infer: f64) {
        if self.cfg.gamma1 == 0.0 {
            return; // fixed mode
        }
        let surplus = queue as f64 * t_infer - self.cfg.interval;
        self.alpha = (self.alpha - self.cfg.gamma1 * surplus).min(1.0).max(0.5);
        self.beta = self.cfg.gamma2 * (1.0 - self.alpha);
    }

    /// Classify a confidence value against the band. Comparison carries an
    /// f32-level epsilon so confidences that *are* the threshold value
    /// (e.g. 0.1f32 vs β=0.1) land on the confident side.
    pub fn decide(&self, confidence: f32) -> BandDecision {
        const EPS: f64 = 1e-6;
        let f = confidence as f64;
        if f >= self.alpha - EPS {
            BandDecision::Positive
        } else if f <= self.beta + EPS {
            BandDecision::Negative
        } else {
            BandDecision::Doubtful
        }
    }

    /// Width of the doubtful band (upload fraction driver).
    pub fn band_width(&self) -> f64 {
        (self.alpha - self.beta).max(0.0)
    }
}

/// Outcome of edge classification against the [β, α] band.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BandDecision {
    /// f ≥ α: confidently a query object.
    Positive,
    /// f ≤ β: confidently not a query object.
    Negative,
    /// β < f < α: upload to the Cloud for re-classification.
    Doubtful,
}

impl BandDecision {
    /// Stable lowercase label (span details, exports).
    pub fn as_str(&self) -> &'static str {
        match self {
            BandDecision::Positive => "positive",
            BandDecision::Negative => "negative",
            BandDecision::Doubtful => "doubtful",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    fn load(id: u32, queue: usize, t: f64) -> NodeLoad {
        NodeLoad { node: NodeId(id), queue, t_infer: t, penalty: 0.0 }
    }

    #[test]
    fn allocate_picks_min_cost() {
        let c = vec![load(1, 10, 0.3), load(2, 2, 0.3), load(0, 4, 0.05)];
        // costs: 3.0, 0.6, 0.2 -> cloud (id 0)
        assert_eq!(allocate(&c), Some(NodeId(0)));
    }

    #[test]
    fn allocate_tie_prefers_first() {
        let c = vec![load(3, 2, 0.5), load(1, 2, 0.5)];
        assert_eq!(allocate(&c), Some(NodeId(3)));
    }

    #[test]
    fn allocate_tie_breaks_to_lower_id_when_first_loses() {
        // Regression: the first (local) entry is NOT part of the tie, so
        // the documented order demands the lowest id among the tied
        // minimum-cost nodes — the old code kept whichever came first.
        let c = vec![load(7, 3, 1.0), load(5, 2, 0.5), load(2, 2, 0.5)];
        // costs: 3.0, 1.0, 1.0 -> tie between id 5 and id 2 -> id 2
        assert_eq!(allocate(&c), Some(NodeId(2)));
    }

    #[test]
    fn record_exclusion_labels_node_and_reason() {
        let reg = Registry::new();
        record_exclusion(&reg, "SE", NodeId::CLOUD, "circuit_open");
        record_exclusion(&reg, "SE", NodeId::CLOUD, "circuit_open");
        assert_eq!(
            reg.counter(
                "surveiledge_sched_skipped_total",
                &[("scheme", "SE"), ("node", "cloud"), ("reason", "circuit_open")],
            ),
            2
        );
    }

    #[test]
    fn prop_allocate_tiebreak_matches_documented_order() {
        check("allocate_tiebreak", |rng, _| {
            // Coarse cost grid so ties are frequent; shuffled distinct ids
            // so "first entry" and "lowest id" genuinely disagree.
            let n = rng.range_usize(1, 8);
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            let c: Vec<NodeLoad> = ids
                .into_iter()
                .map(|id| NodeLoad {
                    node: NodeId(id),
                    queue: rng.range_usize(0, 3),
                    t_infer: 0.5,
                    penalty: 0.0,
                })
                .collect();
            let chosen = allocate(&c).unwrap();
            let min = c.iter().map(|l| l.cost()).fold(f64::INFINITY, f64::min);
            let expect = if (c[0].cost() - min).abs() <= 1e-12 {
                c[0].node // local node is part of the tie: it wins
            } else {
                c.iter().filter(|l| (l.cost() - min).abs() <= 1e-12).map(|l| l.node).min().unwrap()
            };
            assert_eq!(chosen, expect, "candidates {c:?}");
        });
    }

    #[test]
    fn allocate_empty_is_none() {
        assert_eq!(allocate(&[]), None);
    }

    #[test]
    fn allocate_penalty_shifts_choice() {
        // Cloud is idle but upload penalty makes the local edge win.
        let c = vec![
            NodeLoad { node: NodeId(1), queue: 1, t_infer: 0.3, penalty: 0.0 },
            NodeLoad { node: NodeId(0), queue: 0, t_infer: 0.05, penalty: 0.5 },
        ];
        assert_eq!(allocate(&c), Some(NodeId(1)));
    }

    #[test]
    fn prop_allocate_is_argmin() {
        check("allocate_argmin", |rng, _| {
            let n = rng.range_usize(1, 8);
            let c: Vec<NodeLoad> = (0..n)
                .map(|i| NodeLoad {
                    node: NodeId(i as u32),
                    queue: rng.range_usize(0, 50),
                    t_infer: rng.range_f64(0.01, 2.0),
                    penalty: rng.range_f64(0.0, 1.0),
                })
                .collect();
            let chosen = allocate(&c).unwrap();
            let chosen_cost = c.iter().find(|l| l.node == chosen).unwrap().cost();
            for l in &c {
                assert!(chosen_cost <= l.cost() + 1e-9);
            }
        });
    }

    #[test]
    fn weight_penalties_scales_costs_uniformly() {
        let mut c = vec![load(1, 2, 0.3), load(0, 1, 0.05)];
        c[1].penalty = 0.4;
        let base: Vec<f64> = c.iter().map(|l| l.cost()).collect();
        weight_penalties(&mut c, 2.0);
        for (l, b) in c.iter().zip(&base) {
            assert!((l.cost() - 2.0 * b).abs() < 1e-12);
        }
        // Weight 1 and degenerate weights are exact no-ops.
        let snapshot: Vec<f64> = c.iter().map(|l| l.cost()).collect();
        weight_penalties(&mut c, 1.0);
        weight_penalties(&mut c, 0.0);
        weight_penalties(&mut c, f64::NAN);
        let after: Vec<f64> = c.iter().map(|l| l.cost()).collect();
        assert_eq!(snapshot, after);
    }

    #[test]
    fn prop_uniform_weight_preserves_allocation() {
        check("weight_preserves_argmin", |rng, _| {
            let n = rng.range_usize(1, 8);
            let mut c: Vec<NodeLoad> = (0..n)
                .map(|i| NodeLoad {
                    node: NodeId(i as u32),
                    queue: rng.range_usize(0, 50),
                    t_infer: rng.range_f64(0.01, 2.0),
                    penalty: rng.range_f64(0.0, 1.0),
                })
                .collect();
            let before = allocate(&c);
            weight_penalties(&mut c, rng.range_f64(0.25, 4.0));
            assert_eq!(allocate(&c), before, "uniform scaling must not move the argmin");
        });
    }

    #[test]
    fn controller_overload_narrows_band() {
        let mut tc = ThresholdController::new(0.9, ThresholdConfig::default());
        let before = tc.band_width();
        tc.update(30, 0.5); // l_d*t_d = 15 >> s=1
        assert!(tc.alpha < 0.9, "alpha should drop under load");
        assert!(tc.band_width() < before, "band should narrow under load");
    }

    #[test]
    fn controller_headroom_widens_band() {
        let mut tc = ThresholdController::new(0.6, ThresholdConfig::default());
        let before = tc.band_width();
        tc.update(0, 0.1); // idle: surplus = -1
        assert!(tc.alpha > 0.6);
        assert!(tc.band_width() > before);
    }

    #[test]
    fn controller_alpha_clamped() {
        let mut tc = ThresholdController::new(0.99, ThresholdConfig::default());
        for _ in 0..100 {
            tc.update(0, 0.0); // always widening
        }
        assert!(tc.alpha <= 1.0);
        for _ in 0..100 {
            tc.update(1000, 10.0); // always narrowing
        }
        assert!((tc.alpha - 0.5).abs() < 1e-9, "alpha floor is 0.5, got {}", tc.alpha);
    }

    #[test]
    fn prop_invariants_hold_under_any_updates() {
        check("threshold_invariants", |rng, _| {
            let cfg = ThresholdConfig {
                gamma1: rng.range_f64(0.01, 0.99),
                gamma2: rng.range_f64(0.01, 0.99),
                interval: rng.range_f64(0.1, 3.0),
            };
            let mut tc = ThresholdController::new(rng.range_f64(0.0, 1.5), cfg);
            for _ in 0..64 {
                tc.update(rng.range_usize(0, 200), rng.range_f64(0.0, 3.0));
                // Paper's invariants: α ∈ [0.5, 1]; β = γ₂(1-α) < 0.5 ≤ α;
                // band average below 0.5... (α+β)/2 ≤ (1+γ₂·0.5)/2 < 1.
                assert!((0.5..=1.0).contains(&tc.alpha));
                assert!(tc.beta >= 0.0 && tc.beta < 0.5);
                assert!(tc.beta < tc.alpha);
                // mean of α and β stays under (α + γ₂(1-α))/2 which for
                // γ₂<1 is < α ≤ 1; the recall-bias property β < 1-α ⋅ γ₂⁻¹
                // reduces to β = γ₂(1-α):
                assert!((tc.beta - cfg.gamma2 * (1.0 - tc.alpha)).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn decide_band_edges() {
        let tc = ThresholdController::fixed(); // α=0.8, β=0.1
        assert_eq!(tc.decide(0.85), BandDecision::Positive);
        assert_eq!(tc.decide(0.8), BandDecision::Positive);
        assert_eq!(tc.decide(0.5), BandDecision::Doubtful);
        assert_eq!(tc.decide(0.1), BandDecision::Negative);
        assert_eq!(tc.decide(0.05), BandDecision::Negative);
    }

    #[test]
    fn fixed_controller_never_moves() {
        let mut tc = ThresholdController::fixed();
        tc.update(1000, 100.0);
        assert_eq!(tc.alpha, 0.8);
        assert_eq!(tc.beta, 0.1);
    }
}
