//! Distributed parameter database (paper §IV-D-1).
//!
//! The paper keeps α, β, tᵢ and Qᵢ in a SQLite database on every edge,
//! where "the update of any of these parameters will trigger the immediate
//! update" everywhere. This module is that store: a versioned, watchable
//! key-value table with snapshot persistence and an update log, replicated
//! between nodes over the bus ([`crate::bus`]) by the node runtimes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Values the scheduler state needs (kept closed so replication and
/// persistence stay total).
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum Value {
    F64(f64),
    U64(u64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::U64(v) => Some(*v as f64),
            Value::Bool(_) => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::U64(v) => Some(*v),
            _ => None,
        }
    }
}

/// One versioned entry.
#[derive(Clone, Debug)]
struct Entry {
    value: Value,
    version: u64,
}

/// A single observed update (key, value, version).
#[derive(Clone, Debug)]
pub struct Update {
    pub key: String,
    pub value: Value,
    pub version: u64,
}

type Watcher = Box<dyn Fn(&Update) + Send + 'static>;

/// Versioned, watchable KV store. Clones share state (Arc inside), so a
/// node can hand the same DB to its detector, classifier and scheduler
/// threads — mirroring the paper's per-edge shared SQLite file.
#[derive(Clone)]
pub struct ParamDb {
    inner: Arc<Inner>,
}

struct Inner {
    map: Mutex<HashMap<String, Entry>>,
    watchers: Mutex<Vec<Watcher>>,
    clock: AtomicU64,
}

impl Default for ParamDb {
    fn default() -> Self {
        Self::new()
    }
}

impl ParamDb {
    pub fn new() -> ParamDb {
        ParamDb {
            inner: Arc::new(Inner {
                map: Mutex::new(HashMap::new()),
                watchers: Mutex::new(Vec::new()),
                clock: AtomicU64::new(1),
            }),
        }
    }

    /// Write `key`; returns the assigned version. Watchers fire inline
    /// (the paper's "immediate update" trigger semantics).
    pub fn put(&self, key: &str, value: Value) -> u64 {
        let version = self.inner.clock.fetch_add(1, Ordering::Relaxed);
        {
            let mut map = self.inner.map.lock().unwrap();
            map.insert(key.to_string(), Entry { value, version });
        }
        let update = Update { key: key.to_string(), value, version };
        for w in self.inner.watchers.lock().unwrap().iter() {
            w(&update);
        }
        version
    }

    /// Replication entry point: apply a remote update only if it is newer
    /// than what we hold (last-writer-wins by version).
    pub fn merge(&self, update: &Update) -> bool {
        let mut map = self.inner.map.lock().unwrap();
        let apply = map.get(&update.key).is_none_or(|e| update.version > e.version);
        if apply {
            map.insert(update.key.clone(), Entry { value: update.value, version: update.version });
            // Bump the local clock past the remote version so later local
            // writes strictly supersede it.
            let _ = self
                .inner
                .clock
                .fetch_max(update.version + 1, Ordering::Relaxed);
        }
        apply
    }

    pub fn get(&self, key: &str) -> Option<Value> {
        self.inner.map.lock().unwrap().get(key).map(|e| e.value)
    }

    pub fn get_versioned(&self, key: &str) -> Option<(Value, u64)> {
        self.inner.map.lock().unwrap().get(key).map(|e| (e.value, e.version))
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_u64())
    }

    /// Register a watcher called on every local put (and merged update via
    /// [`ParamDb::merge_notify`]).
    pub fn watch<F: Fn(&Update) + Send + 'static>(&self, f: F) {
        self.inner.watchers.lock().unwrap().push(Box::new(f));
    }

    /// Mirror DB activity into a metric registry: every put counts, and
    /// heartbeat keys (`hb/<node>`) additionally count per node — the
    /// liveness signal behind allocator failover.
    pub fn attach_registry(&self, reg: crate::obs::Registry) {
        self.watch(move |u| {
            reg.inc("surveiledge_paramdb_puts_total", &[], 1);
            if let Some(node) = u.key.strip_prefix("hb/") {
                reg.inc("surveiledge_paramdb_heartbeats_total", &[("node", node)], 1);
            }
        });
    }

    /// Merge + fire watchers (used by the replication listener).
    pub fn merge_notify(&self, update: &Update) -> bool {
        let applied = self.merge(update);
        if applied {
            for w in self.inner.watchers.lock().unwrap().iter() {
                w(update);
            }
        }
        applied
    }

    /// Point-in-time snapshot of all entries (persistence / debugging).
    pub fn snapshot(&self) -> Vec<Update> {
        let map = self.inner.map.lock().unwrap();
        let mut out: Vec<Update> = map
            .iter()
            .map(|(k, e)| Update { key: k.clone(), value: e.value, version: e.version })
            .collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }

    /// Restore from a snapshot (merge semantics, so newer local state wins).
    pub fn restore(&self, snapshot: &[Update]) {
        for u in snapshot {
            self.merge(u);
        }
    }

    /// Conventional keys used by the scheduler state (paper: α, β, tᵢ, Qᵢ).
    pub fn key_alpha() -> &'static str {
        "alpha"
    }
    pub fn key_beta() -> &'static str {
        "beta"
    }
    pub fn key_t(node: u32) -> String {
        format!("t/{node}")
    }
    pub fn key_q(node: u32) -> String {
        format!("q/{node}")
    }
    /// Last-heartbeat timestamp of `node` (seconds of scenario time) —
    /// liveness input for allocator failover (extension beyond the paper).
    pub fn key_hb(node: u32) -> String {
        format!("hb/{node}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn put_get_roundtrip() {
        let db = ParamDb::new();
        db.put("alpha", Value::F64(0.8));
        db.put("q/1", Value::U64(5));
        assert_eq!(db.get_f64("alpha"), Some(0.8));
        assert_eq!(db.get_u64("q/1"), Some(5));
        assert_eq!(db.get("missing"), None);
    }

    #[test]
    fn versions_increase() {
        let db = ParamDb::new();
        let v1 = db.put("k", Value::U64(1));
        let v2 = db.put("k", Value::U64(2));
        assert!(v2 > v1);
        assert_eq!(db.get_versioned("k").unwrap().1, v2);
    }

    #[test]
    fn watchers_fire_on_put() {
        let db = ParamDb::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        db.watch(move |u| {
            assert_eq!(u.key, "alpha");
            c.fetch_add(1, Ordering::SeqCst);
        });
        db.put("alpha", Value::F64(0.7));
        db.put("alpha", Value::F64(0.6));
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn merge_respects_versions() {
        let db = ParamDb::new();
        let v = db.put("t/1", Value::F64(0.3));
        // Older remote update is ignored.
        assert!(!db.merge(&Update { key: "t/1".into(), value: Value::F64(9.0), version: v - 1 }));
        assert_eq!(db.get_f64("t/1"), Some(0.3));
        // Newer remote update applies.
        assert!(db.merge(&Update { key: "t/1".into(), value: Value::F64(0.5), version: v + 10 }));
        assert_eq!(db.get_f64("t/1"), Some(0.5));
        // And local writes after a merge supersede it.
        db.put("t/1", Value::F64(0.7));
        assert_eq!(db.get_f64("t/1"), Some(0.7));
    }

    #[test]
    fn replication_converges_two_nodes() {
        // Two DBs exchanging their update streams converge.
        let a = ParamDb::new();
        let b = ParamDb::new();
        a.put("alpha", Value::F64(0.9));
        b.put("beta", Value::F64(0.1));
        b.put("alpha", Value::F64(0.8)); // concurrent write, higher version
        for u in a.snapshot() {
            b.merge(&u);
        }
        for u in b.snapshot() {
            a.merge(&u);
        }
        // Deterministic convergence: same (value, version) on both sides.
        let sa = a.snapshot();
        let sb = b.snapshot();
        assert_eq!(sa.len(), sb.len());
        for (x, y) in sa.iter().zip(sb.iter()) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.version, y.version);
            assert_eq!(x.value, y.value);
        }
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let db = ParamDb::new();
        db.put("alpha", Value::F64(0.75));
        db.put("q/2", Value::U64(7));
        db.put("flag", Value::Bool(true));
        let snap = db.snapshot();
        let fresh = ParamDb::new();
        fresh.restore(&snap);
        assert_eq!(fresh.get_f64("alpha"), Some(0.75));
        assert_eq!(fresh.get_u64("q/2"), Some(7));
        assert_eq!(fresh.get("flag"), Some(Value::Bool(true)));
    }

    #[test]
    fn clone_shares_state() {
        let db = ParamDb::new();
        let db2 = db.clone();
        db.put("x", Value::U64(1));
        assert_eq!(db2.get_u64("x"), Some(1));
    }

    #[test]
    fn key_helpers() {
        assert_eq!(ParamDb::key_t(3), "t/3");
        assert_eq!(ParamDb::key_q(0), "q/0");
        assert_eq!(ParamDb::key_hb(2), "hb/2");
    }

    #[test]
    fn heartbeat_key_roundtrips_and_replicates() {
        // Heartbeats ride the same versioned-merge replication as the
        // scheduler state: a peer that merges the snapshot sees liveness.
        let db = ParamDb::new();
        db.put(&ParamDb::key_hb(1), Value::F64(12.0));
        assert_eq!(db.get_f64(&ParamDb::key_hb(1)), Some(12.0));
        let peer = ParamDb::new();
        for u in db.snapshot() {
            peer.merge(&u);
        }
        assert_eq!(peer.get_f64(&ParamDb::key_hb(1)), Some(12.0));
        assert_eq!(peer.get_f64(&ParamDb::key_hb(9)), None, "never-seen node has no heartbeat");
    }

    #[test]
    fn concurrent_puts_all_land() {
        let db = ParamDb::new();
        let mut handles = Vec::new();
        for t in 0..4 {
            let db = db.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    db.put(&format!("k{t}/{i}"), Value::U64(i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.snapshot().len(), 400);
    }
}
