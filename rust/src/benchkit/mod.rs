//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bench::run`] for micro benches (warmup + timed iterations,
//! mean/p50/p99) and print paper-style tables for the macro experiments.
//! Output is markdown so `EXPERIMENTS.md` can embed it directly.

use std::time::Instant;

/// Result of one micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// The one stable result schema (`crate::obs::Report`, kind
    /// `micro_bench`) shared with `SchemeResult::report()`.
    pub fn report(&self) -> crate::obs::Report {
        let mut r = crate::obs::Report::new("micro_bench", &self.name);
        r.push("iters", self.iters as f64);
        r.push("mean_ns", self.mean_ns);
        r.push("p50_ns", self.p50_ns);
        r.push("p99_ns", self.p99_ns);
        r.push("min_ns", self.min_ns);
        r
    }

    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Micro-benchmark runner.
pub struct Bench {
    /// Target wall time per benchmark (seconds).
    pub target_time: f64,
    /// Warmup time (seconds).
    pub warmup_time: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Env overrides so CI can shrink bench time.
        let target_time = std::env::var("BENCH_TARGET_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Bench { target_time, warmup_time: 0.2, max_iters: 1_000_000, results: Vec::new() }
    }

    /// Time `f` repeatedly; `f` should perform one unit of work and return
    /// a value (returned values are passed through `std::hint::black_box`).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + per-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed().as_secs_f64() < self.warmup_time {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_time / per_iter.max(1e-9)) as usize)
            .clamp(10, self.max_iters);

        let mut samples_ns = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let pct = |q: f64| samples_ns[((q * (samples_ns.len() - 1) as f64) as usize).min(samples_ns.len() - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: pct(0.5),
            p99_ns: pct(0.99),
            min_ns: samples_ns[0],
        };
        println!("{}", result.row());
        self.results.push(result.clone());
        result
    }

    /// Print the table header (call before the first `run`).
    pub fn header(title: &str) {
        println!("\n## {title}\n");
        println!("| benchmark | iters | mean | p50 | p99 | min |");
        println!("|-----------|-------|------|-----|-----|-----|");
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200 s");
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bench { target_time: 0.05, warmup_time: 0.01, max_iters: 100_000, results: vec![] };
        let r = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn row_renders() {
        let r = BenchResult {
            name: "x".into(),
            iters: 100,
            mean_ns: 1000.0,
            p50_ns: 900.0,
            p99_ns: 2000.0,
            min_ns: 800.0,
        };
        let row = r.row();
        assert!(row.contains("| x |"));
        assert!(row.contains("1.00 µs"));
        let rep = r.report();
        assert_eq!(rep.kind, "micro_bench");
        assert_eq!(rep.get("iters"), Some(100.0));
        assert_eq!(rep.get("p99_ns"), Some(2000.0));
    }
}
