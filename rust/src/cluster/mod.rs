//! Camera profiling + K-Means clustering (paper §IV-A).
//!
//! Each camera's *proportion vector* (occurrence frequency of each object
//! class in its leisure-time footage, Fig. 3) is its profile; K-Means over
//! profiles groups analogous-scene cameras, and each cluster shares one
//! context-specific training dataset.

use crate::testkit::Rng;
use crate::types::{CameraId, NUM_CLASSES};

/// A camera profile: normalised class-occurrence frequencies.
#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    pub camera: CameraId,
    pub proportions: [f64; NUM_CLASSES],
}

impl Profile {
    /// Build from raw class counts; uniform if the camera saw nothing.
    pub fn from_counts(camera: CameraId, counts: &[usize; NUM_CLASSES]) -> Profile {
        let total: usize = counts.iter().sum();
        let mut proportions = [1.0 / NUM_CLASSES as f64; NUM_CLASSES];
        if total > 0 {
            for (p, &c) in proportions.iter_mut().zip(counts.iter()) {
                *p = c as f64 / total as f64;
            }
        }
        Profile { camera, proportions }
    }
}

fn dist2(a: &[f64; NUM_CLASSES], b: &[f64; NUM_CLASSES]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// K-Means clustering result.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// `assignment[i]` = cluster index of `profiles[i]`.
    pub assignment: Vec<usize>,
    /// Cluster centres — themselves proportion vectors (the paper calls
    /// the centre "the profile of this cluster").
    pub centres: Vec<[f64; NUM_CLASSES]>,
    /// Within-cluster sum of squared distances.
    pub inertia: f64,
}

impl Clustering {
    pub fn cluster_of(&self, idx: usize) -> usize {
        self.assignment[idx]
    }

    /// Cameras in each cluster.
    pub fn members(&self, k: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); k];
        for (i, &c) in self.assignment.iter().enumerate() {
            out[c].push(i);
        }
        out
    }
}

/// Lloyd's K-Means with k-means++ seeding; deterministic for a given seed.
pub fn kmeans(profiles: &[Profile], k: usize, seed: u64) -> Clustering {
    assert!(k >= 1 && k <= profiles.len().max(1), "bad k={k} for n={}", profiles.len());
    let n = profiles.len();
    let mut rng = Rng::new(seed);

    // k-means++ seeding.
    let mut centres: Vec<[f64; NUM_CLASSES]> = Vec::with_capacity(k);
    centres.push(profiles[rng.range_usize(0, n)].proportions);
    while centres.len() < k {
        let weights: Vec<f64> = profiles
            .iter()
            .map(|p| {
                centres
                    .iter()
                    .map(|c| dist2(&p.proportions, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = weights.iter().sum();
        if total <= 1e-18 {
            // All points coincide with existing centres; duplicate one.
            centres.push(profiles[rng.range_usize(0, n)].proportions);
        } else {
            centres.push(profiles[rng.weighted(&weights)].proportions);
        }
    }

    let mut assignment = vec![0usize; n];
    for _iter in 0..100 {
        // Assign.
        let mut changed = false;
        for (i, p) in profiles.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(&p.proportions, &centres[a])
                        .partial_cmp(&dist2(&p.proportions, &centres[b]))
                        .unwrap()
                })
                .unwrap();
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![[0.0; NUM_CLASSES]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in profiles.iter().enumerate() {
            let c = assignment[i];
            counts[c] += 1;
            for d in 0..NUM_CLASSES {
                sums[c][d] += p.proportions[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..NUM_CLASSES {
                    centres[c][d] = sums[c][d] / counts[c] as f64;
                }
            } else {
                // Empty cluster: reseed on the farthest point.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        dist2(&profiles[a].proportions, &centres[assignment[a]])
                            .partial_cmp(&dist2(&profiles[b].proportions, &centres[assignment[b]]))
                            .unwrap()
                    })
                    .unwrap();
                centres[c] = profiles[far].proportions;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    let inertia = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| dist2(&p.proportions, &centres[assignment[i]]))
        .sum();
    Clustering { assignment, centres, inertia }
}

/// Mean silhouette coefficient of a clustering (quality diagnostic used by
/// `examples/offline_stage.rs` to justify the paper's k=2).
pub fn silhouette(profiles: &[Profile], clustering: &Clustering) -> f64 {
    let n = profiles.len();
    let k = clustering.centres.len();
    if n <= 1 || k <= 1 {
        return 0.0;
    }
    let members = clustering.members(k);
    let mut total = 0.0;
    let mut counted = 0usize;
    for i in 0..n {
        let own = clustering.assignment[i];
        if members[own].len() <= 1 {
            continue; // silhouette undefined for singleton clusters
        }
        let mean_dist = |set: &[usize]| -> f64 {
            let s: f64 = set
                .iter()
                .filter(|&&j| j != i)
                .map(|&j| dist2(&profiles[i].proportions, &profiles[j].proportions).sqrt())
                .sum();
            s / set.iter().filter(|&&j| j != i).count().max(1) as f64
        };
        let a = mean_dist(&members[own]);
        let b = (0..k)
            .filter(|&c| c != own && !members[c].is_empty())
            .map(|c| mean_dist(&members[c]))
            .fold(f64::INFINITY, f64::min);
        if b.is_finite() {
            total += (b - a) / a.max(b);
            counted += 1;
        }
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    fn road_profile(cam: u32, jitter: f64, rng: &mut Rng) -> Profile {
        let mut counts = [0usize; NUM_CLASSES];
        let mix = [34.0, 12.0, 14.0, 16.0, 10.0, 8.0, 2.0, 4.0];
        for (c, m) in counts.iter_mut().zip(mix.iter()) {
            *c = ((m + rng.range_f64(-jitter, jitter)).max(0.0) * 10.0) as usize;
        }
        Profile::from_counts(CameraId(cam), &counts)
    }

    fn square_profile(cam: u32, jitter: f64, rng: &mut Rng) -> Profile {
        let mut counts = [0usize; NUM_CLASSES];
        let mix = [5.0, 2.0, 2.0, 8.0, 16.0, 38.0, 17.0, 12.0];
        for (c, m) in counts.iter_mut().zip(mix.iter()) {
            *c = ((m + rng.range_f64(-jitter, jitter)).max(0.0) * 10.0) as usize;
        }
        Profile::from_counts(CameraId(cam), &counts)
    }

    #[test]
    fn profile_normalised() {
        let p = Profile::from_counts(CameraId(0), &[10, 0, 0, 0, 0, 0, 0, 30]);
        assert!((p.proportions.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p.proportions[0] - 0.25).abs() < 1e-12);
        assert!((p.proportions[7] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn profile_empty_counts_uniform() {
        let p = Profile::from_counts(CameraId(0), &[0; NUM_CLASSES]);
        for v in p.proportions {
            assert!((v - 0.125).abs() < 1e-12);
        }
    }

    #[test]
    fn kmeans_separates_road_from_square() {
        let mut rng = Rng::new(5);
        let mut profiles = Vec::new();
        for i in 0..7 {
            profiles.push(road_profile(i, 2.0, &mut rng));
        }
        for i in 7..14 {
            profiles.push(square_profile(i, 2.0, &mut rng));
        }
        let clus = kmeans(&profiles, 2, 42);
        // All road cameras in one cluster, all square cameras in the other.
        let road_cluster = clus.assignment[0];
        assert!(clus.assignment[..7].iter().all(|&c| c == road_cluster));
        assert!(clus.assignment[7..].iter().all(|&c| c != road_cluster));
        assert!(silhouette(&profiles, &clus) > 0.5);
    }

    #[test]
    fn kmeans_k1_groups_everything() {
        let mut rng = Rng::new(6);
        let profiles: Vec<Profile> = (0..5).map(|i| road_profile(i, 3.0, &mut rng)).collect();
        let clus = kmeans(&profiles, 1, 1);
        assert!(clus.assignment.iter().all(|&c| c == 0));
    }

    #[test]
    fn kmeans_deterministic() {
        let mut rng = Rng::new(7);
        let profiles: Vec<Profile> = (0..10)
            .map(|i| if i % 2 == 0 { road_profile(i, 2.0, &mut rng) } else { square_profile(i, 2.0, &mut rng) })
            .collect();
        let a = kmeans(&profiles, 2, 9);
        let b = kmeans(&profiles, 2, 9);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn prop_centres_are_proportion_vectors() {
        // Paper: "the center of a cluster is also a proportion vector".
        check("kmeans_centres_are_proportions", |rng, _| {
            let n = rng.range_usize(3, 16);
            let k = rng.range_usize(1, n.min(4) + 1);
            let profiles: Vec<Profile> = (0..n)
                .map(|i| {
                    if rng.bool(0.5) {
                        road_profile(i as u32, 5.0, rng)
                    } else {
                        square_profile(i as u32, 5.0, rng)
                    }
                })
                .collect();
            let clus = kmeans(&profiles, k, rng.next_u64());
            for c in &clus.centres {
                let s: f64 = c.iter().sum();
                assert!((s - 1.0).abs() < 1e-9, "centre sums to {s}");
                assert!(c.iter().all(|&v| v >= -1e-12));
            }
            // Every profile assigned to some cluster in range.
            assert!(clus.assignment.iter().all(|&c| c < k));
        });
    }

    #[test]
    fn prop_inertia_nonincreasing_in_k() {
        check("kmeans_inertia_monotone", |rng, _| {
            let n = rng.range_usize(6, 14);
            let profiles: Vec<Profile> = (0..n)
                .map(|i| {
                    if i % 2 == 0 {
                        road_profile(i as u32, 4.0, rng)
                    } else {
                        square_profile(i as u32, 4.0, rng)
                    }
                })
                .collect();
            let seed = rng.next_u64();
            let i1 = kmeans(&profiles, 1, seed).inertia;
            let i2 = kmeans(&profiles, 2, seed).inertia;
            // k=2 is at least as good as k=1 up to k-means++ randomness;
            // allow tiny slack for local optima.
            assert!(i2 <= i1 * 1.05 + 1e-9, "inertia k1={i1} k2={i2}");
        });
    }
}
