# Convenience targets. The Rust build itself is plain cargo (offline,
# path-only deps); see README.md.

.PHONY: build test doc artifacts bench

build:
	cargo build --release

# Tier-1 verification (what CI runs on the default feature set).
test:
	cargo build --release && cargo test -q

doc:
	cargo doc --no-deps

# Build the AOT artifact bundle (needs Python + JAX; runs once).
# Python is build-time only — never on the request path.
artifacts:
	cd python && python -m compile.aot --out ../artifacts/manifest.json

bench:
	cargo bench --bench bench_tables
