"""L2 model tests: shapes, kernel/ref path agreement, (de)serialisation."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import data, model


@pytest.fixture(scope="module")
def edge_params():
    return model.init_params(model.edge_param_manifest(), seed=1)


@pytest.fixture(scope="module")
def cloud_params():
    return model.init_params(model.cloud_param_manifest(), seed=2)


@pytest.fixture(scope="module")
def batch():
    xs, _ = data.make_dataset(4, seed=5)
    return jnp.asarray(xs)


def test_edge_manifest_consistency(edge_params):
    man = model.edge_param_manifest()
    assert len(man) == len(edge_params)
    for (name, shape), p in zip(man, edge_params):
        assert tuple(p.shape) == tuple(shape), name


def test_cloud_manifest_consistency(cloud_params):
    man = model.cloud_param_manifest()
    assert len(man) == len(cloud_params)
    for (name, shape), p in zip(man, cloud_params):
        assert tuple(p.shape) == tuple(shape), name


def test_edge_head_group_is_suffix():
    """Head group entries must be the manifest tail (rust indexes by suffix)."""
    man = model.edge_param_manifest()
    k = model.edge_head_param_count()
    tail = [n for n, _ in man[-k:]]
    assert tail == ["ds3_dw_w", "ds3_dw_b", "ds3_pw_w", "ds3_pw_b", "head_w", "head_b"]


def test_edge_forward_probs(edge_params, batch):
    probs = model.edge_forward(edge_params, batch, use_kernels=False)
    assert probs.shape == (4, 2)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)
    assert (np.asarray(probs) >= 0).all()


def test_cloud_forward_probs(cloud_params, batch):
    probs = model.cloud_forward(cloud_params, batch, use_kernels=False)
    assert probs.shape == (4, data.NUM_CLASSES)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)


def test_edge_kernel_path_matches_ref(edge_params, batch):
    a = model.edge_forward(edge_params, batch, use_kernels=False)
    b = model.edge_forward(edge_params, batch, use_kernels=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_cloud_kernel_path_matches_ref(cloud_params, batch):
    a = model.cloud_forward(cloud_params, batch, use_kernels=False)
    b = model.cloud_forward(cloud_params, batch, use_kernels=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_edge_logits_match_forward(edge_params, batch):
    logits = model.edge_logits(edge_params, batch, use_kernels=False)
    probs = model.edge_forward(edge_params, batch, use_kernels=False)
    np.testing.assert_allclose(np.asarray(model.softmax(logits)), np.asarray(probs),
                               rtol=1e-5, atol=1e-6)


def test_flatten_unflatten_roundtrip(edge_params):
    man = model.edge_param_manifest()
    flat = model.flatten_params(edge_params)
    back = model.unflatten_params(flat, man)
    for p, q in zip(edge_params, back):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_unflatten_rejects_wrong_size():
    man = model.edge_param_manifest()
    n = sum(int(np.prod(s)) for _, s in man)
    with pytest.raises(AssertionError):
        model.unflatten_params(np.zeros(n + 1, np.float32), man)


def test_init_params_deterministic():
    a = model.init_params(model.edge_param_manifest(), seed=9)
    b = model.init_params(model.edge_param_manifest(), seed=9)
    for p, q in zip(a, b):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_normalize_input_centred():
    x = jnp.asarray(np.array([0.0, 0.5, 1.0], np.float32))
    np.testing.assert_allclose(np.asarray(model.normalize_input(x)), [-1.0, 0.0, 1.0])


def test_batch_independence(edge_params):
    """Row i of a batched forward equals a singleton forward (no cross-batch
    leakage through the pallas grid)."""
    xs, _ = data.make_dataset(3, seed=6)
    full = model.edge_forward(edge_params, jnp.asarray(xs), use_kernels=True)
    for i in range(3):
        one = model.edge_forward(edge_params, jnp.asarray(xs[i:i + 1]), use_kernels=True)
        np.testing.assert_allclose(np.asarray(full[i]), np.asarray(one[0]),
                                   rtol=1e-4, atol=1e-5)
