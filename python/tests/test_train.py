"""Training-graph tests: gradients, masking, convergence on tiny problems."""

import numpy as np
import jax.numpy as jnp

from compile import data, model, train


def _tiny_binary(n=96, seed=0):
    return data.make_binary_dataset(n, data.CLS_MOPED, seed=seed)


def test_edge_grad_step_shapes():
    params = model.init_params(model.edge_param_manifest(), seed=1)
    xs, ys = _tiny_binary(32)
    out = train.edge_grad_step(params, jnp.asarray(xs[:32]), jnp.asarray(ys[:32]))
    grads, loss, acc = list(out[:-2]), out[-2], out[-1]
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


def test_gradients_nonzero_everywhere():
    """Every parameter must receive gradient signal (catches dead layers)."""
    params = model.init_params(model.edge_param_manifest(), seed=2)
    xs, ys = _tiny_binary()
    out = train.edge_grad_step(params, jnp.asarray(xs[:32]), jnp.asarray(ys[:32]))
    grads = out[:-2]
    man = model.edge_param_manifest()
    for (name, _), g in zip(man, grads):
        assert float(jnp.abs(g).max()) > 0.0, f"dead gradient: {name}"


def test_mask_freezes_params():
    params = model.init_params(model.edge_param_manifest(), seed=3)
    xs, ys = _tiny_binary()
    mask = [False] * (len(params) - 2) + [True, True]
    newp, _ = train.train_loop(model.edge_logits, 2, params, xs, ys,
                               steps=3, batch=16, lr=1e-2, mask=mask)
    for i, (p, q) in enumerate(zip(params, newp)):
        if mask[i]:
            assert float(jnp.abs(p - q).max()) > 0.0, f"masked-in param {i} did not move"
        else:
            np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_head_finetune_converges():
    """Head-only fine-tuning on an easy binary problem reaches >80%."""
    params = model.init_params(model.edge_param_manifest(), seed=4)
    xs, ys = data.make_binary_dataset(512, data.CLS_BUS, seed=5)
    xt, yt = data.make_binary_dataset(128, data.CLS_BUS, seed=6)
    newp, _ = train.train_loop(model.edge_logits, 2, params, xs, ys,
                               steps=120, batch=32, lr=3e-3)
    acc = train.evaluate(model.edge_logits, 2, newp, xt, yt)
    assert acc > 0.8, f"full train acc only {acc}"


def test_loss_decreases():
    params = model.init_params(model.cloud_param_manifest(), seed=7)
    xs, ys = data.make_dataset(256, seed=8)
    step = train.grad_step(model.cloud_logits, data.NUM_CLASSES)
    bx, by = jnp.asarray(xs[:64]), jnp.asarray(ys[:64])
    _, loss0, _ = step(params, bx, by)
    newp, _ = train.train_loop(model.cloud_logits, data.NUM_CLASSES, params, xs, ys,
                               steps=40, batch=64, lr=2e-3)
    _, loss1, _ = step(newp, bx, by)
    assert float(loss1) < float(loss0)


def test_adam_and_momentum_update_move_params():
    params = model.init_params(model.edge_param_manifest(), seed=9)
    grads = [jnp.ones_like(p) for p in params]
    for opt in (train.Adam(params, 1e-3), train.Momentum(params, 1e-3)):
        newp = opt.update(params, grads)
        assert any(float(jnp.abs(p - q).max()) > 0 for p, q in zip(params, newp))
