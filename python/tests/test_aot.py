"""Artifact-bundle tests: manifest <-> file consistency (runs only when
``make artifacts`` has produced a bundle)."""

import json
import os
import struct

import numpy as np
import pytest

from compile import data, model

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTDIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def read_blob(path):
    with open(path, "rb") as f:
        (n,) = struct.unpack("<Q", f.read(8))
        arr = np.frombuffer(f.read(), np.float32)
    assert arr.size == n, f"{path}: header says {n}, got {arr.size}"
    return arr


def test_all_artifact_files_exist(manifest):
    for name, art in manifest["artifacts"].items():
        path = os.path.join(ARTDIR, art["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) == art["bytes"], name
    for _, fn in manifest["weights"].items():
        assert os.path.exists(os.path.join(ARTDIR, fn))


def test_hlo_text_parses_as_hlo(manifest):
    """Every artifact must be HLO text (ENTRY present), not a proto dump."""
    for name, art in manifest["artifacts"].items():
        with open(os.path.join(ARTDIR, art["file"])) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
        assert "ENTRY" in head or "ENTRY" in open(os.path.join(ARTDIR, art["file"])).read(), name


def test_weight_blob_sizes_match_manifests(manifest):
    eman = model.edge_param_manifest()
    cman = model.cloud_param_manifest()
    esize = sum(int(np.prod(s)) for _, s in eman)
    csize = sum(int(np.prod(s)) for _, s in cman)
    assert read_blob(os.path.join(ARTDIR, "edge_pretrained.bin")).size == esize
    assert read_blob(os.path.join(ARTDIR, "cloud_trained.bin")).size == csize


def test_manifest_param_entries_match_model(manifest):
    for entry, (name, shape) in zip(manifest["edge_params"], model.edge_param_manifest()):
        assert entry["name"] == name and tuple(entry["shape"]) == tuple(shape)
    for entry, (name, shape) in zip(manifest["cloud_params"], model.cloud_param_manifest()):
        assert entry["name"] == name and tuple(entry["shape"]) == tuple(shape)


def test_trained_accuracy_recorded(manifest):
    """The bundle must carry usable weights: cloud near-oracle, edge in the
    paper's 'lightweight CNN' band (clearly above chance, clearly below cloud)."""
    acc = manifest["train_acc"]
    assert acc["cloud"] >= 0.93, acc
    assert 0.5 <= acc["edge8"] <= acc["cloud"], acc


def test_golden_blob_shapes(manifest):
    g = read_blob(os.path.join(ARTDIR, "golden_sprites.bin"))
    assert g.size == data.NUM_CLASSES * 24 * 24 * 3
    b = read_blob(os.path.join(ARTDIR, "golden_batch.bin"))
    assert b.size == 8 * data.IMG * data.IMG * 3
    ep = read_blob(os.path.join(ARTDIR, "golden_edge_probs.bin"))
    cp = read_blob(os.path.join(ARTDIR, "golden_cloud_probs.bin"))
    assert ep.size == 8 * 2 and cp.size == 8 * data.NUM_CLASSES
    np.testing.assert_allclose(ep.reshape(8, 2).sum(-1), 1.0, atol=1e-4)
    np.testing.assert_allclose(cp.reshape(8, 8).sum(-1), 1.0, atol=1e-4)


def test_golden_probs_reproducible(manifest):
    """Re-running the forward pass on stored weights reproduces the golden
    probabilities (pins weight serialisation + model numerics)."""
    import jax.numpy as jnp
    eman = model.edge_param_manifest()
    flat = read_blob(os.path.join(ARTDIR, "edge_pretrained.bin"))
    params = model.unflatten_params(flat, eman)
    batch = read_blob(os.path.join(ARTDIR, "golden_batch.bin")).reshape(8, data.IMG, data.IMG, 3)
    probs = np.asarray(model.edge_forward(params, jnp.asarray(batch), use_kernels=False))
    want = read_blob(os.path.join(ARTDIR, "golden_edge_probs.bin")).reshape(8, 2)
    np.testing.assert_allclose(probs, want, rtol=1e-4, atol=1e-5)


def test_cloud_is_near_oracle_on_fresh_data(manifest):
    """The deployed cloud weights must behave as the ground-truth CNN."""
    import jax.numpy as jnp
    cman = model.cloud_param_manifest()
    flat = read_blob(os.path.join(ARTDIR, "cloud_trained.bin"))
    params = model.unflatten_params(flat, cman)
    xs, ys = data.make_dataset(256, seed=777)
    probs = np.asarray(model.cloud_forward(params, jnp.asarray(xs), use_kernels=False))
    acc = (probs.argmax(-1) == ys).mean()
    assert acc >= 0.9, f"cloud acc {acc} on fresh data"
