"""Synthetic corpus spec tests: determinism, hash pinning, resize, sampling.

The pinned hash/noise values here are duplicated in
``rust/src/video/sprite.rs`` unit tests — if either side drifts, both test
suites fail, which is what keeps the training distribution (python) equal to
the serving distribution (rust).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data

SETTINGS = dict(max_examples=30, deadline=None)


def test_hash32_pinned_values():
    """Cross-language pins: same constants asserted in rust/src/video/sprite.rs."""
    xs = np.array([0, 1, 2, 12345, 0xFFFFFFFF], np.uint32)
    got = data._hash32(xs)
    # reference values computed once from the spec; pinned in both languages
    want = np.array([0, 1753845952, 3507691905, 2435775735, 1734902346], np.uint32)
    np.testing.assert_array_equal(got, want)


def test_pixel_noise_range_and_determinism():
    ys, xs = np.meshgrid(np.arange(16, dtype=np.uint32), np.arange(16, dtype=np.uint32), indexing="ij")
    a = data.pixel_noise(xs, ys, 42)
    b = data.pixel_noise(xs, ys, 42)
    c = data.pixel_noise(xs, ys, 43)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a).max() <= 1.0
    assert not np.array_equal(a, c)
    # noise is not degenerate
    assert a.std() > 0.3


@settings(**SETTINGS)
@given(cls=st.integers(0, data.NUM_CLASSES - 1), size=st.integers(10, 40),
       seed=st.integers(0, 2**31 - 1))
def test_render_sprite_shape_range(cls, size, seed):
    p = data.SpriteParams(cls=cls, size=size, base=(0.8, 0.2, 0.2),
                          accent=(0.2, 0.2, 0.8), bg=(0.5, 0.5, 0.5),
                          noise=0.1, seed=seed)
    img = data.render_sprite(p)
    assert img.shape == (size, size, 3)
    assert img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_render_sprite_deterministic():
    p = data.SpriteParams(cls=3, size=24, base=(0.7, 0.3, 0.1),
                          accent=(0.1, 0.6, 0.7), bg=(0.45, 0.45, 0.45),
                          rot=0.2, jx=0.05, jy=-0.03, noise=0.08, seed=99)
    a, b = data.render_sprite(p), data.render_sprite(p)
    np.testing.assert_array_equal(a, b)


def test_render_sprite_classes_differ():
    """Distinct classes must render visibly distinct sprites."""
    imgs = []
    for cls in range(data.NUM_CLASSES):
        p = data.SpriteParams(cls=cls, size=24, base=(0.8, 0.2, 0.2),
                              accent=(0.2, 0.2, 0.8), bg=(0.5, 0.5, 0.5))
        imgs.append(data.render_sprite(p))
    for i in range(len(imgs)):
        for j in range(i + 1, len(imgs)):
            assert np.abs(imgs[i] - imgs[j]).mean() > 0.005, (i, j)


def test_sprite_differs_from_background():
    p = data.SpriteParams(cls=0, size=24, base=(0.9, 0.1, 0.1),
                          accent=(0.1, 0.1, 0.9), bg=(0.5, 0.5, 0.5))
    img = data.render_sprite(p)
    bg = np.full_like(img, 0.5)
    frac = (np.abs(img - bg).max(axis=-1) > 0.05).mean()
    assert 0.1 < frac < 0.9  # sprite covers a sane fraction of the canvas


@settings(**SETTINGS)
@given(ih=st.integers(4, 40), iw=st.integers(4, 40), seed=st.integers(0, 2**31 - 1))
def test_bilinear_resize_identity(ih, iw, seed):
    rng = np.random.RandomState(seed)
    img = rng.rand(ih, iw, 3).astype(np.float32)
    out = data.bilinear_resize(img, ih, iw)
    np.testing.assert_allclose(out, img, atol=1e-6)


@settings(**SETTINGS)
@given(s=st.integers(4, 40), seed=st.integers(0, 2**31 - 1))
def test_bilinear_resize_constant_preserved(s, seed):
    rng = np.random.RandomState(seed)
    c = rng.rand(3).astype(np.float32)
    img = np.broadcast_to(c, (s, s, 3)).astype(np.float32)
    out = data.bilinear_resize(img, 32, 32)
    np.testing.assert_allclose(out, np.broadcast_to(c, (32, 32, 3)), atol=1e-6)


def test_bilinear_resize_range_bounded():
    rng = np.random.RandomState(0)
    img = rng.rand(17, 23, 3).astype(np.float32)
    out = data.bilinear_resize(img, 32, 32)
    assert out.min() >= img.min() - 1e-6 and out.max() <= img.max() + 1e-6


def test_make_dataset_labels_and_shapes():
    xs, ys = data.make_dataset(64, seed=7)
    assert xs.shape == (64, data.IMG, data.IMG, 3)
    assert ys.shape == (64,)
    assert ys.min() >= 0 and ys.max() < data.NUM_CLASSES
    assert xs.dtype == np.float32


def test_make_dataset_class_weights():
    w = np.zeros(data.NUM_CLASSES)
    w[2] = 1.0
    _, ys = data.make_dataset(32, seed=8, class_weights=w)
    assert (ys == 2).all()


def test_make_binary_dataset_proportional_negatives():
    """Paper §IV-B: negatives sampled proportionally to the cluster profile."""
    profile = np.zeros(data.NUM_CLASSES)
    profile[data.CLS_MOPED] = 0.5   # query class: must be excluded from negatives
    profile[data.CLS_CAR] = 0.5
    xs, ys = data.make_binary_dataset(200, data.CLS_MOPED, seed=9, profile=profile,
                                      pos_frac=0.5)
    assert set(np.unique(ys)).issubset({0, 1})
    assert 0.3 < ys.mean() < 0.7


def test_make_binary_dataset_deterministic():
    a = data.make_binary_dataset(16, 3, seed=10)
    b = data.make_binary_dataset(16, 3, seed=10)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
