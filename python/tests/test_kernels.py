"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/strides/activations; assert_allclose against ref.
This is the core correctness signal for the compute layer: the inference
artifacts lower through these kernels, the training graph through the refs.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, depthwise, dense, pointwise, framediff
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    ci=st.integers(1, 6),
    co=st.sampled_from([2, 4, 8]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    act=st.sampled_from([ref.ACT_NONE, ref.ACT_RELU, ref.ACT_RELU6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, h, w, ci, co, k, stride, act, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, b, h, w, ci)
    wt = _rand(rng, k, k, ci, co)
    bias = _rand(rng, co)
    got = conv2d(x, wt, bias, stride=stride, act=act)
    want = ref.conv2d(x, wt, bias, stride=stride, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    act=st.sampled_from([ref.ACT_NONE, ref.ACT_RELU, ref.ACT_RELU6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_ref(b, h, w, c, stride, act, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, b, h, w, c)
    wt = _rand(rng, 3, 3, c)
    bias = _rand(rng, c)
    got = depthwise(x, wt, bias, stride=stride, act=act)
    want = ref.depthwise(x, wt, bias, stride=stride, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 32),
    nt=st.sampled_from([(4, 1), (4, 2), (8, 4), (12, 3), (16, 16)]),
    act=st.sampled_from([ref.ACT_NONE, ref.ACT_RELU, ref.ACT_RELU6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, nt, act, seed):
    n, tile = nt
    rng = np.random.RandomState(seed)
    x = _rand(rng, m, k)
    wt = _rand(rng, k, n)
    bias = _rand(rng, n)
    got = dense(x, wt, bias, act=act, n_tile=tile)
    want = ref.dense(x, wt, bias, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(6, 24),
    w=st.integers(6, 24),
    ci=st.integers(1, 8),
    co=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pointwise_matches_ref(b, h, w, ci, co, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, b, h, w, ci)
    wt = _rand(rng, ci, co)
    bias = _rand(rng, co)
    got = pointwise(x, wt, bias, act=ref.ACT_RELU6)
    flat = ref.dense(x.reshape(b * h * w, ci), wt, bias, act=ref.ACT_RELU6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(flat).reshape(b, h, w, co),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 32),
    w=st.integers(4, 32),
    thr=st.floats(0.02, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_framediff_matches_ref(b, h, w, thr, seed):
    rng = np.random.RandomState(seed)
    frames = [jnp.asarray(rng.rand(b, h, w, 3).astype(np.float32)) for _ in range(3)]
    got = framediff(*frames, threshold=thr)
    want = ref.framediff(*frames, threshold=thr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_framediff_static_scene_is_empty():
    """No motion => empty mask, regardless of scene content."""
    rng = np.random.RandomState(0)
    f = jnp.asarray(rng.rand(1, 16, 16, 3).astype(np.float32))
    mask = framediff(f, f, f, threshold=0.05)
    assert float(jnp.sum(mask)) == 0.0


def test_framediff_detects_moving_block():
    """A block moving across an otherwise static scene is detected at its
    current location (conjunction of the two difference images)."""
    base = np.full((1, 24, 24, 3), 0.5, np.float32)
    prev, cur, nxt = base.copy(), base.copy(), base.copy()
    prev[0, 4:10, 2:8] = 1.0
    cur[0, 4:10, 8:14] = 1.0
    nxt[0, 4:10, 14:20] = 1.0
    mask = np.asarray(framediff(jnp.asarray(prev), jnp.asarray(cur), jnp.asarray(nxt),
                                threshold=0.1))
    # mask must fire inside the current block position...
    assert mask[0, 6:8, 10:12].min() == 1.0
    # ...and be silent far away from all three positions
    assert mask[0, 18:, :].max() == 0.0


def test_framediff_binary_output():
    rng = np.random.RandomState(1)
    frames = [jnp.asarray(rng.rand(2, 12, 12, 3).astype(np.float32)) for _ in range(3)]
    mask = np.asarray(framediff(*frames, threshold=0.2))
    assert set(np.unique(mask)).issubset({0.0, 1.0})


def test_conv2d_tiled_equals_untiled():
    rng = np.random.RandomState(3)
    x = _rand(rng, 2, 8, 8, 4)
    wt = _rand(rng, 3, 3, 4, 8)
    bias = _rand(rng, 8)
    a = conv2d(x, wt, bias, stride=1, cout_tile=4)
    b = conv2d(x, wt, bias, stride=1, cout_tile=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_dense_rejects_bad_tile():
    rng = np.random.RandomState(4)
    with pytest.raises(AssertionError):
        dense(_rand(rng, 2, 4), _rand(rng, 4, 6), _rand(rng, 6), n_tile=4)
