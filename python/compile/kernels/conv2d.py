"""Pallas conv2d kernel: im2col -> MXU matmul.

TPU adaptation of the dense-conv hot loop (DESIGN.md §Hardware-Adaptation):
instead of porting a CUDA threadblock conv, the convolution is phrased as an
(M, K) x (K, N) matmul so the inner loop is a single ``jnp.dot`` that maps
onto the MXU systolic array. The grid runs one program per (image,
out-channel tile); BlockSpec streams one padded image + one weight tile into
VMEM per step, which is the HBM<->VMEM schedule a GPU kernel would express
with threadblocks + shared memory.

VMEM budget per program (f32): padded image H'*W'*Ci + weight tile
KH*KW*Ci*Tc + output tile Ho*Wo*Tc — sized well under 2 MiB for every layer
in this repo (see DESIGN.md §Perf).

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, stride, ho, wo, act):
    """One program: one padded image x one out-channel tile."""
    x = x_ref[0]            # (Hp, Wp, Ci)
    ci = x.shape[-1]
    # im2col: KH*KW strided views, stacked on a new trailing axis.
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(
                jax.lax.slice(
                    x,
                    (dy, dx, 0),
                    (dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1, ci),
                    (stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=2)                    # (Ho, Wo, KH*KW, Ci)
    m = patches.reshape(ho * wo, kh * kw * ci)           # (M, K)
    wmat = w_ref[...].reshape(kh * kw * ci, -1)          # (K, Tc)
    acc = jnp.dot(m, wmat, preferred_element_type=jnp.float32)
    out = acc.reshape(ho, wo, -1) + b_ref[...]
    o_ref[0] = ref.apply_act(out, act)


@functools.partial(jax.jit, static_argnames=("stride", "act", "cout_tile"))
def conv2d(x, w, b, *, stride: int = 1, act: int = ref.ACT_NONE, cout_tile: int = 0):
    """NHWC SAME conv via pallas. x (B,H,W,Ci), w (KH,KW,Ci,Co), b (Co)."""
    bsz, h, wdt, ci = x.shape
    kh, kw, _, co = w.shape
    tc = cout_tile if cout_tile > 0 else co
    assert co % tc == 0, f"cout {co} not divisible by tile {tc}"
    plo, phi = ref.same_pads(kh, stride, h)
    qlo, qhi = ref.same_pads(kw, stride, wdt)
    xp = jnp.pad(x, ((0, 0), (plo, phi), (qlo, qhi), (0, 0)))
    hp, wp = h + plo + phi, wdt + qlo + qhi
    ho, wo = -(-h // stride), -(-wdt // stride)

    kern = functools.partial(_kernel, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo, act=act)
    return pl.pallas_call(
        kern,
        grid=(bsz, co // tc),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ci), lambda ib, ic: (ib, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, tc), lambda ib, ic: (0, 0, 0, ic)),
            pl.BlockSpec((tc,), lambda ib, ic: (ic,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, tc), lambda ib, ic: (ib, 0, 0, ic)),
        out_shape=jax.ShapeDtypeStruct((bsz, ho, wo, co), jnp.float32),
        interpret=True,
    )(xp, w, b)
