"""L1 Pallas kernels (build-time only; lowered into the model HLO)."""

from .conv2d import conv2d
from .dense import dense, pointwise
from .depthwise import depthwise
from .framediff import framediff

__all__ = ["conv2d", "dense", "pointwise", "depthwise", "framediff"]
