"""Pallas depthwise-conv kernel (MobileNet's core op).

Depthwise conv has no channel contraction, so it is a VPU (vector unit)
kernel, not an MXU one: per grid step we stream one padded image into
VMEM and accumulate KH*KW shifted, strided slices scaled by the per-channel
taps — elementwise MACs over a (Ho, Wo, C) tile. Channels stay in the minor
dimension (lane axis on TPU) so the multiply broadcasts across lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, stride, ho, wo, act):
    x = x_ref[0]                          # (Hp, Wp, C)
    c = x.shape[-1]
    acc = jnp.zeros((ho, wo, c), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            sl = jax.lax.slice(
                x,
                (dy, dx, 0),
                (dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + sl * w_ref[dy, dx]  # (C,) broadcast over lanes
    o_ref[0] = ref.apply_act(acc + b_ref[...], act)


@functools.partial(jax.jit, static_argnames=("stride", "act"))
def depthwise(x, w, b, *, stride: int = 1, act: int = ref.ACT_NONE):
    """NHWC SAME depthwise conv via pallas. x (B,H,W,C), w (KH,KW,C), b (C)."""
    bsz, h, wdt, c = x.shape
    kh, kw, _ = w.shape
    plo, phi = ref.same_pads(kh, stride, h)
    qlo, qhi = ref.same_pads(kw, stride, wdt)
    xp = jnp.pad(x, ((0, 0), (plo, phi), (qlo, qhi), (0, 0)))
    hp, wp = h + plo + phi, wdt + qlo + qhi
    ho, wo = -(-h // stride), -(-wdt // stride)

    kern = functools.partial(_kernel, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo, act=act)
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda ib: (ib, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda ib: (0, 0, 0)),
            pl.BlockSpec((c,), lambda ib: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda ib: (ib, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, ho, wo, c), jnp.float32),
        interpret=True,
    )(xp, w, b)
