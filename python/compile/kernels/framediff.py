"""Pallas frame-difference kernel (paper §IV-C dense stage).

The per-frame detection hot loop: three consecutive frames in, one binary
motion mask out. All stages are fused into a single VPU kernel so the
triplet is read from HBM exactly once:

    d1 = |f_k - f_{k-1}|;  d2 = |f_{k+1} - f_k|
    da = min(d1, d2)                  (elementwise conjunction, eq. 3)
    gray = mean_c(da)                 (grayscale)
    bin  = gray > threshold           (eq. 4, maxval normalised to 1.0)
    dil  = 3x3 max-filter(bin)        (eq. 5, dilation)
    ero  = 3x3 min-filter(dil)        (eq. 6, erosion)

Grid = one program per triplet; the (H, W, 3) blocks stay channel-minor so
the abs/min/mean run across lanes. Morphology shifts are static slices of a
zero/one-padded VMEM tile. Contour extraction (irregular, data-dependent)
stays in the Rust coordinator (rust/src/detect) per DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _morph(x, op):
    """3x3 max/min filter over (H, W) with neutral-value padding."""
    pad_val = 0.0 if op == "max" else 1.0
    xp = jnp.pad(x, ((1, 1), (1, 1)), constant_values=pad_val)
    h, w = x.shape
    out = xp[0:h, 0:w]
    for dy in range(3):
        for dx in range(3):
            if dy == 0 and dx == 0:
                continue
            sl = xp[dy:dy + h, dx:dx + w]
            out = jnp.maximum(out, sl) if op == "max" else jnp.minimum(out, sl)
    return out


def _kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, threshold):
    prev, cur, nxt = prev_ref[0], cur_ref[0], nxt_ref[0]
    d1 = jnp.abs(cur - prev)
    d2 = jnp.abs(nxt - cur)
    da = jnp.minimum(d1, d2)
    gray = jnp.mean(da, axis=-1)
    binary = (gray > threshold).astype(jnp.float32)
    o_ref[0] = _morph(_morph(binary, "max"), "min")


@functools.partial(jax.jit, static_argnames=("threshold",))
def framediff(prev, cur, nxt, *, threshold: float = 0.1):
    """(B,H,W,3) triplet -> (B,H,W) binary motion mask."""
    bsz, h, w, c = prev.shape
    kern = functools.partial(_kernel, threshold=threshold)
    spec = pl.BlockSpec((1, h, w, c), lambda ib: (ib, 0, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, h, w), lambda ib: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, w), jnp.float32),
        interpret=True,
    )(prev, cur, nxt)
