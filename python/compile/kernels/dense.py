"""Pallas fused dense (+bias +activation) kernel.

Used both for classifier heads and for MobileNet pointwise (1x1) convs: a
pointwise conv over NHWC is exactly ``reshape(B*H*W, Ci) @ (Ci, Co)``.
Grid tiles the N (out-feature) axis so each program computes an
(M, K) x (K, Tn) MXU matmul with the weight tile resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = ref.apply_act(acc + b_ref[...], act)


@functools.partial(jax.jit, static_argnames=("act", "n_tile"))
def dense(x, w, b, *, act: int = ref.ACT_NONE, n_tile: int = 0):
    """x (M,K) @ w (K,N) + b (N), fused activation."""
    m, k = x.shape
    _, n = w.shape
    tn = n_tile if n_tile > 0 else n
    assert n % tn == 0, f"n {n} not divisible by tile {tn}"
    kern = functools.partial(_kernel, act=act)
    return pl.pallas_call(
        kern,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, tn), lambda i: (0, i)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def pointwise(x, w, b, *, act: int = ref.ACT_NONE):
    """1x1 conv over NHWC via the dense kernel. x (B,H,W,Ci), w (Ci,Co)."""
    bsz, h, wdt, ci = x.shape
    co = w.shape[1]
    out = dense(x.reshape(bsz * h * wdt, ci), w, b, act=act)
    return out.reshape(bsz, h, wdt, co)
