"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the implementations used inside the *training* graph
(``compile/train.py``): JAX cannot differentiate through interpret-mode
pallas_call cleanly, and the kernels only need to be on the inference hot
path. ``python/tests/test_kernels.py`` asserts kernel == ref across a
hypothesis sweep of shapes/dtypes, which pins both paths together.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Activation codes shared with the pallas kernels.
ACT_NONE, ACT_RELU, ACT_RELU6 = 0, 1, 2


def apply_act(x, act: int):
    if act == ACT_NONE:
        return x
    if act == ACT_RELU:
        return jnp.maximum(x, 0.0)
    if act == ACT_RELU6:
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(f"bad act {act}")


def same_pads(k: int, s: int, size: int):
    """TF-style SAME padding amounts (lo, hi) for kernel k, stride s."""
    out = -(-size // s)  # ceil div
    pad = max(0, (out - 1) * s + k - size)
    return pad // 2, pad - pad // 2


def conv2d(x, w, b, *, stride: int = 1, act: int = ACT_NONE):
    """NHWC conv, SAME padding. x (B,H,W,Ci), w (KH,KW,Ci,Co), b (Co)."""
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return apply_act(out + b, act)


def depthwise(x, w, b, *, stride: int = 1, act: int = ACT_NONE):
    """Depthwise NHWC conv, SAME. x (B,H,W,C), w (KH,KW,C), b (C)."""
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x, w[:, :, None, :],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return apply_act(out + b, act)


def dense(x, w, b, *, act: int = ACT_NONE):
    """x (B,K) @ w (K,N) + b (N)."""
    return apply_act(jnp.dot(x, w) + b, act)


def framediff(prev, cur, nxt, *, threshold: float = 0.1):
    """Paper §IV-C dense stage on a frame triplet (B,H,W,3) -> (B,H,W) mask.

    d1 = |f_k - f_{k-1}|, d2 = |f_{k+1} - f_k|; the paper's per-element
    "bitwise logical conjunction" is realised as the elementwise minimum
    (the t-norm AND for intensity images); grayscale = channel mean;
    fixed-level threshold -> binary; 3x3 dilation then 3x3 erosion
    (morphological closing).  Output in {0, 1} f32.
    """
    d1 = jnp.abs(cur - prev)
    d2 = jnp.abs(nxt - cur)
    da = jnp.minimum(d1, d2)
    gray = jnp.mean(da, axis=-1)
    binary = (gray > threshold).astype(jnp.float32)
    dil = window_morph(binary, op="max")
    ero = window_morph(dil, op="min")
    return ero


def window_morph(x, *, op: str):
    """3x3 dilation (max) / erosion (min) over (B,H,W) with edge-neutral pad."""
    pad_val = 0.0 if op == "max" else 1.0
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1)), constant_values=pad_val)
    h, w = x.shape[1], x.shape[2]
    shifts = [xp[:, dy:dy + h, dx:dx + w] for dy in range(3) for dx in range(3)]
    stack = jnp.stack(shifts, axis=0)
    return jnp.max(stack, axis=0) if op == "max" else jnp.min(stack, axis=0)
