"""Training graphs: cross-entropy loss + gradient step.

Two uses:
  1. Build-time pretraining in ``aot.py`` (python-side loop, jitted).
  2. The ``edge_train`` AOT artifact: a single step lowered to HLO that the
     Rust coordinator calls during *online* fine-tuning (paper §IV-B).

The artifact returns raw gradients (+ loss + batch accuracy) and the Rust
side applies momentum-SGD itself. Keeping the optimizer in Rust is what
makes the paper's three training schemes (Fig. 5) expressible with one HLO:
"fine-tune" masks updates to the head group, "all fine-tune" updates
everything, "no fine-tune" never calls it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model


def ce_loss_and_acc(logits_fn, params, x, y, num_classes: int):
    """Mean softmax cross-entropy + accuracy. y: int labels (B,)."""
    logits = logits_fn(params, x, use_kernels=False)
    logz = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, num_classes, dtype=jnp.float32)
    loss = -jnp.mean(jnp.sum(onehot * logz, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def grad_step(logits_fn, num_classes: int):
    """Returns f(params, x, y) -> (grads, loss, acc)."""
    def loss_fn(params, x, y):
        return ce_loss_and_acc(logits_fn, params, x, y, num_classes)

    def step(params, x, y):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        return grads, loss, acc

    return step


def edge_grad_step(params, x, y):
    """The lowered ``edge_train`` entry point: flat param list in, flat
    gradient list out (same manifest order), plus loss and accuracy."""
    grads, loss, acc = grad_step(model.edge_logits, model.EDGE_HEAD_CLASSES)(params, x, y)
    return tuple(grads) + (loss, acc)


# ---------------------------------------------------------------------------
# Build-time training loop (python side only)
# ---------------------------------------------------------------------------

class Momentum:
    def __init__(self, params, lr: float, mu: float = 0.9):
        self.lr, self.mu = lr, mu
        self.vel = [jnp.zeros_like(p) for p in params]

    def update(self, params, grads, mask=None):
        newp, newv = [], []
        for i, (p, g, v) in enumerate(zip(params, grads, self.vel)):
            if mask is not None and not mask[i]:
                newp.append(p)
                newv.append(v)
                continue
            v2 = self.mu * v - self.lr * g
            newp.append(p + v2)
            newv.append(v2)
        self.vel = newv
        return newp


class Adam:
    def __init__(self, params, lr: float, b1=0.9, b2=0.999, eps=1e-8):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.m = [jnp.zeros_like(p) for p in params]
        self.v = [jnp.zeros_like(p) for p in params]
        self.t = 0

    def update(self, params, grads, mask=None):
        self.t += 1
        c1 = 1.0 - self.b1 ** self.t
        c2 = 1.0 - self.b2 ** self.t
        newp = []
        for i, (p, g) in enumerate(zip(params, grads)):
            if mask is not None and not mask[i]:
                newp.append(p)
                continue
            self.m[i] = self.b1 * self.m[i] + (1 - self.b1) * g
            self.v[i] = self.b2 * self.v[i] + (1 - self.b2) * g * g
            mh = self.m[i] / c1
            vh = self.v[i] / c2
            newp.append(p - self.lr * mh / (jnp.sqrt(vh) + self.eps))
        return newp


def train_loop(logits_fn, num_classes, params, xs, ys, *, steps, batch, lr,
               seed=0, mask=None, log_every=0, eval_data=None, opt="adam"):
    """Jitted training loop (Adam by default) with warmup + cosine decay
    over an in-memory dataset."""
    step_fn = jax.jit(grad_step(logits_fn, num_classes))
    opt = Adam(params, lr) if opt == "adam" else Momentum(params, lr)
    rng = np.random.RandomState(seed)
    n = xs.shape[0]
    history = []
    warmup = max(steps // 20, 1)
    for it in range(steps):
        if it < warmup:
            opt.lr = lr * (it + 1) / warmup
        else:
            t = (it - warmup) / max(steps - warmup, 1)
            opt.lr = lr * 0.5 * (1.0 + np.cos(np.pi * t))
        idx = rng.randint(0, n, size=batch)
        bx = jnp.asarray(xs[idx])
        by = jnp.asarray(ys[idx])
        grads, loss, acc = step_fn(params, bx, by)
        params = opt.update(params, grads, mask=mask)
        if log_every and (it % log_every == 0 or it == steps - 1):
            ev = evaluate(logits_fn, num_classes, params, *eval_data) if eval_data else float(acc)
            history.append((it, float(loss), ev))
            print(f"  step {it:4d} loss {float(loss):.4f} acc {ev:.4f}")
    return params, history


def evaluate(logits_fn, num_classes, params, xs, ys, batch=256):
    fwd = jax.jit(functools.partial(logits_fn, use_kernels=False))
    correct, total = 0, 0
    for off in range(0, xs.shape[0], batch):
        bx = jnp.asarray(xs[off:off + batch])
        by = ys[off:off + batch]
        pred = np.asarray(jnp.argmax(fwd(params, bx), axis=-1))
        correct += int((pred == by).sum())
        total += by.shape[0]
    return correct / max(total, 1)
