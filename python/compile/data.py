"""Synthetic surveillance-object corpus (build-time side).

This module is the *specification* of the sprite renderer: the Rust serving
substrate (``rust/src/video/sprite.rs``) implements the exact same per-pixel
analytic rasterizer, so the distribution the edge/cloud CNNs are trained on
(here) matches the distribution the detector crops at serving time (there).

Design rules that make the two implementations bit-comparable:

* Shapes are **analytic masks** evaluated per pixel in canonical coordinates
  (u, v) in [-1, 1]^2 — no curve rasterisation, no anti-aliasing.
* All arithmetic is f32.
* Per-pixel noise comes from an integer hash (``pixel_noise``), not a
  stateful RNG, so it is identical across languages given (x, y, seed).
* Bilinear resize uses the half-pixel-center convention (align_corners=False)
  with edge clamping.

A golden test (``python/tests/test_golden.py`` + ``rust/src/video/sprite.rs``
tests against ``artifacts/golden_sprites.bin``) pins the two implementations
together.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Class ids — keep in sync with rust/src/types (ClassId).
CLASSES = ["car", "bus", "truck", "moped", "bicycle", "person", "dog", "cart"]
NUM_CLASSES = len(CLASSES)
CLS_CAR, CLS_BUS, CLS_TRUCK, CLS_MOPED = 0, 1, 2, 3
CLS_BICYCLE, CLS_PERSON, CLS_DOG, CLS_CART = 4, 5, 6, 7

IMG = 32  # CNN input resolution (IMG x IMG x 3)

WHEEL = np.array([0.13, 0.13, 0.15], np.float32)  # dark wheel/tyre colour


@dataclasses.dataclass
class SpriteParams:
    """Fully explicit, RNG-free description of one rendered object."""

    cls: int
    size: int                 # raster canvas (size x size), sprite fills it
    base: tuple               # primary body colour (r, g, b) in [0, 1]
    accent: tuple             # secondary colour
    bg: tuple                 # background colour
    rot: float = 0.0          # rotation, radians (small)
    jx: float = 0.0           # centre jitter in canonical units
    jy: float = 0.0
    noise: float = 0.0        # additive noise amplitude
    seed: int = 0             # pixel-noise seed


# ----------------------------------------------------------------------------
# Deterministic per-pixel noise (cross-language identical)
# ----------------------------------------------------------------------------

def _hash32(x: np.ndarray) -> np.ndarray:
    """lowbias32 integer hash (u32 -> u32); same constants in Rust."""
    x = x.astype(np.uint32)
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(0x7FEB352D)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(15)
    x = (x * np.uint32(0x846CA68B)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(16)
    return x


def pixel_noise(xs: np.ndarray, ys: np.ndarray, seed: int) -> np.ndarray:
    """Uniform noise in [-1, 1] per pixel, vectorised; f32."""
    h = _hash32(
        (xs.astype(np.uint32) * np.uint32(73856093))
        ^ (ys.astype(np.uint32) * np.uint32(19349663))
        ^ np.uint32((seed * 83492791) & 0xFFFFFFFF)
    )
    return (h.astype(np.float32) / np.float32(4294967295.0)) * np.float32(2.0) - np.float32(1.0)


# ----------------------------------------------------------------------------
# Analytic masks. All take canonical coords (u right, v down) and return bool.
# ----------------------------------------------------------------------------

def _rect(u, v, cx, cy, hw, hh):
    return (np.abs(u - cx) <= hw) & (np.abs(v - cy) <= hh)


def _ellipse(u, v, cx, cy, ru, rv):
    return ((u - cx) / ru) ** 2 + ((v - cy) / rv) ** 2 <= 1.0


def _circle(u, v, cx, cy, r):
    return _ellipse(u, v, cx, cy, r, r)


def _ring(u, v, cx, cy, r, w):
    d2 = (u - cx) ** 2 + (v - cy) ** 2
    return (d2 <= (r + w) ** 2) & (d2 >= (r - w) ** 2)


def _seg(u, v, x1, y1, x2, y2, w):
    """Distance-to-segment <= w."""
    dx, dy = x2 - x1, y2 - y1
    ll = dx * dx + dy * dy
    t = np.clip(((u - x1) * dx + (v - y1) * dy) / np.maximum(ll, 1e-9), 0.0, 1.0)
    px, py = x1 + t * dx, y1 + t * dy
    return (u - px) ** 2 + (v - py) ** 2 <= w * w


def class_layers(cls: int, base, accent):
    """Ordered (mask_fn, colour) layers for a class. Later layers paint over."""
    b = np.asarray(base, np.float32)
    a = np.asarray(accent, np.float32)
    w = WHEEL
    if cls == CLS_CAR:
        return [
            (lambda u, v: _rect(u, v, 0.0, 0.08, 0.72, 0.26), b),
            (lambda u, v: _rect(u, v, -0.05, -0.22, 0.36, 0.16), a),
            (lambda u, v: _circle(u, v, -0.42, 0.42, 0.16), w),
            (lambda u, v: _circle(u, v, 0.42, 0.42, 0.16), w),
        ]
    if cls == CLS_BUS:
        return [
            (lambda u, v: _rect(u, v, 0.0, 0.0, 0.85, 0.45), b),
            (lambda u, v: _rect(u, v, 0.0, -0.2, 0.75, 0.1), a),
            (lambda u, v: _circle(u, v, -0.5, 0.5, 0.14), w),
            (lambda u, v: _circle(u, v, 0.5, 0.5, 0.14), w),
        ]
    if cls == CLS_TRUCK:
        return [
            (lambda u, v: _rect(u, v, -0.58, 0.0, 0.2, 0.3), a),
            (lambda u, v: _rect(u, v, 0.18, -0.08, 0.55, 0.38), b),
            (lambda u, v: _circle(u, v, -0.58, 0.42, 0.13), w),
            (lambda u, v: _circle(u, v, 0.05, 0.44, 0.13), w),
            (lambda u, v: _circle(u, v, 0.6, 0.44, 0.13), w),
        ]
    if cls == CLS_MOPED:
        return [
            (lambda u, v: _circle(u, v, -0.45, 0.42, 0.2), w),
            (lambda u, v: _circle(u, v, 0.45, 0.42, 0.2), w),
            (lambda u, v: _rect(u, v, 0.08, 0.08, 0.28, 0.2), b),
            (lambda u, v: _seg(u, v, 0.35, -0.3, 0.3, 0.1, 0.06), a),
            (lambda u, v: _rect(u, v, 0.35, -0.35, 0.14, 0.05), a),
            (lambda u, v: _rect(u, v, -0.28, -0.1, 0.16, 0.07), b),
        ]
    if cls == CLS_BICYCLE:
        return [
            (lambda u, v: _ring(u, v, -0.45, 0.32, 0.3, 0.07), w),
            (lambda u, v: _ring(u, v, 0.45, 0.32, 0.3, 0.07), w),
            (lambda u, v: _seg(u, v, -0.45, 0.32, 0.05, -0.3, 0.05), b),
            (lambda u, v: _seg(u, v, 0.05, -0.3, 0.45, 0.32, 0.05), b),
            (lambda u, v: _seg(u, v, -0.45, 0.32, 0.0, 0.32, 0.05), b),
            (lambda u, v: _rect(u, v, 0.05, -0.38, 0.12, 0.04), a),
        ]
    if cls == CLS_PERSON:
        return [
            (lambda u, v: _rect(u, v, -0.1, 0.55, 0.08, 0.3), a),
            (lambda u, v: _rect(u, v, 0.12, 0.55, 0.08, 0.3), a),
            (lambda u, v: _ellipse(u, v, 0.0, -0.02, 0.24, 0.38), b),
            (lambda u, v: _circle(u, v, 0.0, -0.56, 0.18), a),
        ]
    if cls == CLS_DOG:
        return [
            (lambda u, v: _rect(u, v, -0.3, 0.5, 0.06, 0.22), b),
            (lambda u, v: _rect(u, v, 0.3, 0.5, 0.06, 0.22), b),
            (lambda u, v: _ellipse(u, v, 0.0, 0.12, 0.48, 0.24), b),
            (lambda u, v: _circle(u, v, 0.52, -0.1, 0.17), b),
            (lambda u, v: _seg(u, v, -0.48, 0.0, -0.68, -0.3, 0.05), b),
        ]
    if cls == CLS_CART:
        return [
            (lambda u, v: _rect(u, v, 0.1, -0.02, 0.48, 0.3), b),
            (lambda u, v: _circle(u, v, 0.1, 0.45, 0.18), w),
            (lambda u, v: _seg(u, v, -0.38, -0.1, -0.75, -0.45, 0.05), a),
        ]
    raise ValueError(f"bad class {cls}")


def render_sprite(p: SpriteParams) -> np.ndarray:
    """Rasterise one sprite onto its background; returns (size, size, 3) f32."""
    s = p.size
    idx = np.arange(s, dtype=np.float32)
    # half-pixel centres mapped to [-1, 1]
    u = ((2.0 * idx + 1.0) / np.float32(s) - 1.0)[None, :] * np.ones((s, 1), np.float32)
    v = ((2.0 * idx + 1.0) / np.float32(s) - 1.0)[:, None] * np.ones((1, s), np.float32)
    # inverse-transform pixel coords into canonical sprite space
    uc = (u - np.float32(p.jx)).astype(np.float32)
    vc = (v - np.float32(p.jy)).astype(np.float32)
    c, sn = np.float32(np.cos(p.rot)), np.float32(np.sin(p.rot))
    ur = uc * c + vc * sn
    vr = -uc * sn + vc * c

    img = np.empty((s, s, 3), np.float32)
    img[:] = np.asarray(p.bg, np.float32)
    for mask_fn, colour in class_layers(p.cls, p.base, p.accent):
        m = mask_fn(ur, vr)
        img[m] = colour

    if p.noise > 0.0:
        ys, xs = np.meshgrid(np.arange(s, dtype=np.uint32), np.arange(s, dtype=np.uint32), indexing="ij")
        for ch in range(3):
            n = pixel_noise(xs, ys, p.seed + ch * 1013904223)
            img[:, :, ch] += np.float32(p.noise) * n
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def bilinear_resize(img: np.ndarray, oh: int, ow: int) -> np.ndarray:
    """Half-pixel-centre bilinear resize with edge clamp; (H,W,C) f32."""
    ih, iw = img.shape[0], img.shape[1]
    ry = np.float32(ih) / np.float32(oh)
    rx = np.float32(iw) / np.float32(ow)
    ys = (np.arange(oh, dtype=np.float32) + 0.5) * ry - 0.5
    xs = (np.arange(ow, dtype=np.float32) + 0.5) * rx - 0.5
    y0 = np.clip(np.floor(ys), 0, ih - 1).astype(np.int32)
    x0 = np.clip(np.floor(xs), 0, iw - 1).astype(np.int32)
    y1 = np.minimum(y0 + 1, ih - 1)
    x1 = np.minimum(x0 + 1, iw - 1)
    fy = np.clip(ys - y0.astype(np.float32), 0.0, 1.0).astype(np.float32)[:, None, None]
    fx = np.clip(xs - x0.astype(np.float32), 0.0, 1.0).astype(np.float32)[None, :, None]
    a = img[y0][:, x0]
    b = img[y0][:, x1]
    c = img[y1][:, x0]
    d = img[y1][:, x1]
    top = a * (1.0 - fx) + b * fx
    bot = c * (1.0 - fx) + d * fx
    return (top * (1.0 - fy) + bot * fy).astype(np.float32)


# ----------------------------------------------------------------------------
# Corpus sampling
# ----------------------------------------------------------------------------

def sample_params(rng: np.random.RandomState, cls: int, *, hard: bool = True) -> SpriteParams:
    """Sample render params. ``hard`` adds the jitter/noise that separates
    edge-CNN accuracy from cloud-CNN accuracy (the paper's accuracy gap)."""
    def colour(lo=0.15, hi=0.95):
        return tuple(rng.uniform(lo, hi, 3).astype(np.float32).tolist())

    size = int(rng.randint(14, 31))
    p = SpriteParams(
        cls=cls,
        size=size,
        base=colour(),
        accent=colour(),
        bg=tuple((np.array([0.45, 0.47, 0.44], np.float32) + rng.uniform(-0.18, 0.18, 3).astype(np.float32)).tolist()),
        rot=float(rng.uniform(-0.35, 0.35)) if hard else 0.0,
        jx=float(rng.uniform(-0.12, 0.12)) if hard else 0.0,
        jy=float(rng.uniform(-0.12, 0.12)) if hard else 0.0,
        noise=float(rng.uniform(0.02, 0.14)) if hard else 0.0,
        seed=int(rng.randint(0, 2**31 - 1)),
    )
    return p


def render_example(p: SpriteParams) -> np.ndarray:
    """Render + resize to the CNN input resolution."""
    return bilinear_resize(render_sprite(p), IMG, IMG)


def make_dataset(n: int, seed: int, class_weights=None, hard: bool = True):
    """Build (x, y): x (n, IMG, IMG, 3) f32, y (n,) int32.

    ``class_weights`` mirrors the paper's proportional negative sampling: a
    length-8 vector of per-class probabilities (the cluster profile).
    """
    rng = np.random.RandomState(seed)
    if class_weights is None:
        class_weights = np.full(NUM_CLASSES, 1.0 / NUM_CLASSES)
    class_weights = np.asarray(class_weights, np.float64)
    class_weights = class_weights / class_weights.sum()
    ys = rng.choice(NUM_CLASSES, size=n, p=class_weights).astype(np.int32)
    xs = np.stack([render_example(sample_params(rng, int(c), hard=hard)) for c in ys])
    return xs.astype(np.float32), ys


def make_binary_dataset(n: int, query_cls: int, seed: int, profile=None, pos_frac: float = 0.5):
    """Query-specific dataset: label 1 = query class, 0 = other.

    Negatives are sampled proportionally to ``profile`` (the cluster
    proportion vector) per the paper's negative-selection rule (§IV-B).
    """
    rng = np.random.RandomState(seed)
    if profile is None:
        profile = np.full(NUM_CLASSES, 1.0 / NUM_CLASSES)
    neg_w = np.asarray(profile, np.float64).copy()
    neg_w[query_cls] = 0.0
    if neg_w.sum() <= 0:
        neg_w = np.ones(NUM_CLASSES)
        neg_w[query_cls] = 0.0
    neg_w = neg_w / neg_w.sum()
    xs, ys = [], []
    for _ in range(n):
        if rng.uniform() < pos_frac:
            c = query_cls
        else:
            c = int(rng.choice(NUM_CLASSES, p=neg_w))
        xs.append(render_example(sample_params(rng, c)))
        ys.append(1 if c == query_cls else 0)
    return np.stack(xs).astype(np.float32), np.asarray(ys, np.int32)
