"""AOT build pipeline (`make artifacts`). Python runs ONCE, here.

Outputs under ``artifacts/``:
  * ``*.hlo.txt``           — HLO text modules (edge/cloud inference at batch
                              1 and 8, edge train step, framediff) that the
                              Rust PJRT runtime loads. HLO *text* (not
                              serialized proto) is mandatory: xla_extension
                              0.5.1 rejects jax>=0.5 64-bit-id protos.
  * ``edge_pretrained.bin`` — generic EdgeCNN weights (backbone pretrained on
                              the 8-class corpus + generic 2-class query head)
  * ``cloud_trained.bin``   — high-accuracy CloudCNN weights (ground truth)
  * ``manifest.json``       — shapes, param manifests, artifact inventory
  * ``golden_*.bin``        — cross-language golden vectors pinning the Rust
                              sprite renderer / resize / CNN numerics

Weights are runtime *arguments* to the HLO (never baked constants), so one
compiled executable serves every fine-tuned weight version.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data, model, train

FRAME_H, FRAME_W = 96, 128  # detection frame resolution (see rust/src/video)
EDGE_TRAIN_BATCH = 32
QUERY_CLS = data.CLS_MOPED  # paper's running example query object


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def write_blob(path, arr: np.ndarray):
    """Raw little-endian f32 blob with an 8-byte length header."""
    arr = np.ascontiguousarray(arr, np.float32)
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", arr.size))
        f.write(arr.tobytes())


# ---------------------------------------------------------------------------
# Stage 1: build-time training
# ---------------------------------------------------------------------------

def train_cloud(args):
    """Train the ground-truth CloudCNN to high accuracy on the full corpus."""
    print("[aot] rendering cloud corpus ...")
    xs, ys = data.make_dataset(args.cloud_corpus, seed=11)
    xt, yt = data.make_dataset(1024, seed=12)
    params = model.init_params(model.cloud_param_manifest(), seed=1)
    print(f"[aot] training CloudCNN ({sum(int(np.prod(s)) for _, s in model.cloud_param_manifest())} params, "
          f"{args.cloud_steps} steps) ...")
    t0 = time.time()
    params, _ = train.train_loop(
        model.cloud_logits, model.CLOUD_HEAD_CLASSES, params, xs, ys,
        steps=args.cloud_steps, batch=64, lr=2e-3, seed=2,
        log_every=max(args.cloud_steps // 8, 1), eval_data=(xt, yt))
    acc = train.evaluate(model.cloud_logits, model.CLOUD_HEAD_CLASSES, params, xt, yt)
    print(f"[aot] CloudCNN test acc {acc:.4f} in {time.time()-t0:.1f}s")
    return params, acc


def pretrain_edge(args):
    """Pretrain the EdgeCNN backbone on the generic 8-class corpus (the
    'ImageNet pre-training' stand-in), then train a generic 2-class query
    head (the 'No Fine-tune' scheme's weights)."""
    print("[aot] rendering edge pretraining corpus ...")
    xs, ys = data.make_dataset(args.edge_corpus, seed=21)
    xt, yt = data.make_dataset(512, seed=22)

    # 8-class pretraining uses a temporary 8-class head on the same backbone.
    man8 = model.edge_param_manifest()[:-2] + [
        ("head8_w", (model.EDGE_FEAT, data.NUM_CLASSES)), ("head8_b", (data.NUM_CLASSES,))]
    params8 = model.init_params(man8, seed=3)
    print(f"[aot] pretraining EdgeCNN backbone ({args.edge_steps} steps) ...")
    params8, _ = train.train_loop(
        model.edge_logits, data.NUM_CLASSES, params8, xs, ys,
        steps=args.edge_steps, batch=64, lr=2e-3, seed=4,
        log_every=max(args.edge_steps // 6, 1), eval_data=(xt, yt))
    acc8 = train.evaluate(model.edge_logits, data.NUM_CLASSES, params8, xt, yt)
    print(f"[aot] EdgeCNN 8-class pretrain acc {acc8:.4f}")

    # Swap the 8-class head for a fresh 2-class query head and give it a
    # short generic (non-context-specific) training run: these are the
    # weights an edge would use with *no* fine-tuning.
    backbone = params8[:-2]
    head = model.init_params([("head_w", (model.EDGE_FEAT, 2)), ("head_b", (2,))], seed=5)
    params2 = backbone + head
    bx, by = data.make_binary_dataset(2048, QUERY_CLS, seed=23)
    mask = [False] * len(backbone) + [True, True]  # head-only generic training
    params2, _ = train.train_loop(
        model.edge_logits, 2, params2, bx, by,
        steps=args.edge_head_steps, batch=64, lr=5e-3, seed=6, mask=mask)
    btx, bty = data.make_binary_dataset(512, QUERY_CLS, seed=24)
    acc2 = train.evaluate(model.edge_logits, 2, params2, btx, bty)
    print(f"[aot] EdgeCNN generic-head binary acc {acc2:.4f}")
    return params2, acc8, acc2


# ---------------------------------------------------------------------------
# Stage 2: lowering
# ---------------------------------------------------------------------------

def lower_all(outdir, edge_params, cloud_params):
    f32 = jnp.float32
    eman, cman = model.edge_param_manifest(), model.cloud_param_manifest()
    especs = [jax.ShapeDtypeStruct(s, f32) for _, s in eman]
    cspecs = [jax.ShapeDtypeStruct(s, f32) for _, s in cman]
    arts = {}

    for bsz in (1, 8):
        x = jax.ShapeDtypeStruct((bsz, data.IMG, data.IMG, 3), f32)

        def edge_infer(*a):
            return (model.edge_forward(list(a[:-1]), a[-1], use_kernels=True),)

        n = lower_to_file(edge_infer, especs + [x], f"{outdir}/edge_infer_b{bsz}.hlo.txt")
        arts[f"edge_infer_b{bsz}"] = {"file": f"edge_infer_b{bsz}.hlo.txt", "batch": bsz, "bytes": n}
        print(f"[aot] lowered edge_infer_b{bsz} ({n} chars)")

        def cloud_infer(*a):
            return (model.cloud_forward(list(a[:-1]), a[-1], use_kernels=True),)

        n = lower_to_file(cloud_infer, cspecs + [x], f"{outdir}/cloud_infer_b{bsz}.hlo.txt")
        arts[f"cloud_infer_b{bsz}"] = {"file": f"cloud_infer_b{bsz}.hlo.txt", "batch": bsz, "bytes": n}
        print(f"[aot] lowered cloud_infer_b{bsz} ({n} chars)")

    xtr = jax.ShapeDtypeStruct((EDGE_TRAIN_BATCH, data.IMG, data.IMG, 3), f32)
    ytr = jax.ShapeDtypeStruct((EDGE_TRAIN_BATCH,), jnp.int32)

    def edge_train_step(*a):
        params, x, y = list(a[:-2]), a[-2], a[-1]
        return train.edge_grad_step(params, x, y)

    n = lower_to_file(edge_train_step, especs + [xtr, ytr], f"{outdir}/edge_train_b{EDGE_TRAIN_BATCH}.hlo.txt")
    arts["edge_train"] = {"file": f"edge_train_b{EDGE_TRAIN_BATCH}.hlo.txt", "batch": EDGE_TRAIN_BATCH, "bytes": n}
    print(f"[aot] lowered edge_train ({n} chars)")

    from .kernels import framediff as k_framediff
    ftrip = jax.ShapeDtypeStruct((1, FRAME_H, FRAME_W, 3), f32)

    def fd(prev, cur, nxt):
        return (k_framediff(prev, cur, nxt, threshold=0.1),)

    n = lower_to_file(fd, [ftrip, ftrip, ftrip], f"{outdir}/framediff.hlo.txt")
    arts["framediff"] = {"file": "framediff.hlo.txt", "batch": 1, "bytes": n,
                         "frame": [FRAME_H, FRAME_W], "threshold": 0.1}
    print(f"[aot] lowered framediff ({n} chars)")
    return arts


# ---------------------------------------------------------------------------
# Stage 3: golden vectors (cross-language pinning)
# ---------------------------------------------------------------------------

def write_golden(outdir, edge_params, cloud_params):
    """Golden sprites + CNN outputs the Rust tests compare against."""
    sprites, meta = [], []
    for cls in range(data.NUM_CLASSES):
        p = data.SpriteParams(
            cls=cls, size=24,
            base=(0.8, 0.2, 0.2), accent=(0.2, 0.3, 0.8), bg=(0.45, 0.47, 0.44),
            rot=0.15, jx=0.05, jy=-0.04, noise=0.06, seed=1000 + cls)
        img = data.render_sprite(p)
        sprites.append(img.ravel())
        meta.append({"cls": cls, "size": 24, "rot": 0.15, "jx": 0.05, "jy": -0.04,
                     "noise": 0.06, "seed": 1000 + cls,
                     "base": [0.8, 0.2, 0.2], "accent": [0.2, 0.3, 0.8],
                     "bg": [0.45, 0.47, 0.44]})
    write_blob(f"{outdir}/golden_sprites.bin", np.concatenate(sprites))

    # resize golden: sprite 24 -> 32
    img24 = data.render_sprite(data.SpriteParams(
        cls=0, size=24, base=(0.7, 0.5, 0.1), accent=(0.1, 0.1, 0.9),
        bg=(0.5, 0.5, 0.5), rot=0.0, jx=0.0, jy=0.0, noise=0.0, seed=7))
    write_blob(f"{outdir}/golden_resize_in.bin", img24)
    write_blob(f"{outdir}/golden_resize_out.bin", data.bilinear_resize(img24, 32, 32))

    # CNN inference goldens on a fixed batch of 8 (one per class)
    batch = np.stack([data.render_example(data.SpriteParams(
        cls=c, size=22, base=(0.6, 0.25, 0.3), accent=(0.25, 0.6, 0.3),
        bg=(0.45, 0.47, 0.44), rot=-0.1, jx=0.02, jy=0.03, noise=0.05,
        seed=2000 + c)) for c in range(8)])
    eout = np.asarray(model.edge_forward(edge_params, jnp.asarray(batch), use_kernels=False))
    cout = np.asarray(model.cloud_forward(cloud_params, jnp.asarray(batch), use_kernels=False))
    write_blob(f"{outdir}/golden_batch.bin", batch)
    write_blob(f"{outdir}/golden_edge_probs.bin", eout)
    write_blob(f"{outdir}/golden_cloud_probs.bin", cout)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--cloud-steps", type=int, default=int(os.environ.get("AOT_CLOUD_STEPS", 400)))
    ap.add_argument("--edge-steps", type=int, default=int(os.environ.get("AOT_EDGE_STEPS", 250)))
    ap.add_argument("--edge-head-steps", type=int, default=120)
    ap.add_argument("--cloud-corpus", type=int, default=int(os.environ.get("AOT_CLOUD_CORPUS", 6000)))
    ap.add_argument("--edge-corpus", type=int, default=int(os.environ.get("AOT_EDGE_CORPUS", 4000)))
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    t0 = time.time()
    cloud_params, cloud_acc = train_cloud(args)
    edge_params, edge_acc8, edge_acc2 = pretrain_edge(args)

    write_blob(f"{outdir}/cloud_trained.bin", model.flatten_params(cloud_params))
    write_blob(f"{outdir}/edge_pretrained.bin", model.flatten_params(edge_params))

    arts = lower_all(outdir, edge_params, cloud_params)
    golden_meta = write_golden(outdir, edge_params, cloud_params)

    manifest = {
        "version": 1,
        "img": data.IMG,
        "frame": [FRAME_H, FRAME_W],
        "classes": data.CLASSES,
        "query_cls": QUERY_CLS,
        "edge_train_batch": EDGE_TRAIN_BATCH,
        "edge_params": [{"name": n, "shape": list(s)} for n, s in model.edge_param_manifest()],
        "cloud_params": [{"name": n, "shape": list(s)} for n, s in model.cloud_param_manifest()],
        "edge_head_group": model.edge_head_param_count(),
        "artifacts": arts,
        "weights": {
            "edge_pretrained": "edge_pretrained.bin",
            "cloud_trained": "cloud_trained.bin",
        },
        "train_acc": {"cloud": cloud_acc, "edge8": edge_acc8, "edge_generic_binary": edge_acc2},
        "golden": golden_meta,
    }
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time()-t0:.1f}s -> {outdir}")


if __name__ == "__main__":
    main()
