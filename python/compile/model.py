"""L2 JAX models: EdgeCNN (CQ-specific, MobileNet-style) and CloudCNN
(high-accuracy, ResNet-style).

Both models are defined over explicit parameter lists (ordered ``(name,
shape)`` manifests) rather than a framework pytree, because the Rust runtime
feeds weights positionally into the AOT HLO executables.

``use_kernels=True`` routes the forward pass through the L1 Pallas kernels
(inference artifacts); ``use_kernels=False`` uses the pure-jnp ref ops
(training graph — differentiable). ``python/tests/test_model.py`` asserts
both paths agree.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import data
from .kernels import ref
from .kernels import conv2d as k_conv2d, depthwise as k_depthwise
from .kernels import dense as k_dense, pointwise as k_pointwise

IMG = data.IMG
NUM_CLASSES = data.NUM_CLASSES

# ---------------------------------------------------------------------------
# EdgeCNN: stem conv + 4 depthwise-separable blocks + GAP + 2-class head.
# ~15k params; the "CQ-specific CNN" the paper fine-tunes per query/cluster.
# (stride, cout) per ds block; stem is 3x3 s2 3->16.
# ---------------------------------------------------------------------------

EDGE_BLOCKS = [(1, 32), (2, 64), (1, 64), (2, 128)]
EDGE_STEM = 16
EDGE_FEAT = EDGE_BLOCKS[-1][1]
EDGE_HEAD_CLASSES = 2  # (not-query, query)


def edge_param_manifest():
    """Ordered (name, shape) list. Head params are last (fine-tune groups)."""
    man = [("stem_w", (3, 3, 3, EDGE_STEM)), ("stem_b", (EDGE_STEM,))]
    cin = EDGE_STEM
    for i, (_, cout) in enumerate(EDGE_BLOCKS):
        man += [
            (f"ds{i}_dw_w", (3, 3, cin)), (f"ds{i}_dw_b", (cin,)),
            (f"ds{i}_pw_w", (cin, cout)), (f"ds{i}_pw_b", (cout,)),
        ]
        cin = cout
    man += [("head_w", (EDGE_FEAT, EDGE_HEAD_CLASSES)), ("head_b", (EDGE_HEAD_CLASSES,))]
    return man


def edge_head_param_count():
    """Number of trailing manifest entries that form the fine-tune head group
    (head + last ds block), mirroring the paper's partial fine-tuning."""
    return 2 + 4  # head_w/head_b + ds3 (dw_w, dw_b, pw_w, pw_b)


def edge_forward(params, x, *, use_kernels: bool):
    """params: list of arrays per edge_param_manifest(); x (B,32,32,3).
    Returns softmax probs (B, 2); probs[:, 1] is the query confidence f."""
    x = normalize_input(x)
    it = iter(params)
    nxt = lambda: next(it)
    sw, sb = nxt(), nxt()
    if use_kernels:
        h = k_conv2d(x, sw, sb, stride=2, act=ref.ACT_RELU6)
    else:
        h = ref.conv2d(x, sw, sb, stride=2, act=ref.ACT_RELU6)
    for stride, _ in EDGE_BLOCKS:
        dww, dwb, pww, pwb = nxt(), nxt(), nxt(), nxt()
        if use_kernels:
            h = k_depthwise(h, dww, dwb, stride=stride, act=ref.ACT_RELU6)
            h = k_pointwise(h, pww, pwb, act=ref.ACT_RELU6)
        else:
            h = ref.depthwise(h, dww, dwb, stride=stride, act=ref.ACT_RELU6)
            b, hh, ww, ci = h.shape
            h = ref.dense(h.reshape(b * hh * ww, ci), pww, pwb, act=ref.ACT_RELU6)
            h = h.reshape(b, hh, ww, -1)
    feat = jnp.mean(h, axis=(1, 2))  # GAP -> (B, EDGE_FEAT)
    hw, hb = nxt(), nxt()
    if use_kernels:
        logits = k_dense(feat, hw, hb, act=ref.ACT_NONE)
    else:
        logits = ref.dense(feat, hw, hb, act=ref.ACT_NONE)
    return softmax(logits)


def normalize_input(x):
    """[0,1] pixels -> zero-centred. Baked into every graph so the Rust
    runtime always feeds raw [0,1] crops."""
    return (x - 0.5) * 2.0


def softmax(logits):
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def edge_logits(params, x, *, use_kernels: bool):
    """Same as edge_forward but returns raw logits (training graph)."""
    x = normalize_input(x)
    it = iter(params)
    nxt = lambda: next(it)
    sw, sb = nxt(), nxt()
    conv = k_conv2d if use_kernels else ref.conv2d
    dw = k_depthwise if use_kernels else ref.depthwise
    h = conv(x, sw, sb, stride=2, act=ref.ACT_RELU6)
    for stride, _ in EDGE_BLOCKS:
        dww, dwb, pww, pwb = nxt(), nxt(), nxt(), nxt()
        h = dw(h, dww, dwb, stride=stride, act=ref.ACT_RELU6)
        b, hh, ww, ci = h.shape
        if use_kernels:
            h = k_pointwise(h, pww, pwb, act=ref.ACT_RELU6)
        else:
            h = ref.dense(h.reshape(b * hh * ww, ci), pww, pwb, act=ref.ACT_RELU6).reshape(b, hh, ww, -1)
    feat = jnp.mean(h, axis=(1, 2))
    hw, hb = nxt(), nxt()
    if use_kernels:
        return k_dense(feat, hw, hb, act=ref.ACT_NONE)
    return ref.dense(feat, hw, hb, act=ref.ACT_NONE)


# ---------------------------------------------------------------------------
# CloudCNN: stem s2 + 3 residual stages + GAP + 8-class head. The paper's
# "high-accuracy CNN" (ResNet-152 stand-in, treated as ground truth).
# ---------------------------------------------------------------------------

CLOUD_STAGES = [16, 32, 64]  # channels per stage; 1 residual block each
CLOUD_HEAD_CLASSES = NUM_CLASSES


def cloud_param_manifest():
    man = [("stem_w", (3, 3, 3, CLOUD_STAGES[0])), ("stem_b", (CLOUD_STAGES[0],))]
    cin = CLOUD_STAGES[0]
    for s, ch in enumerate(CLOUD_STAGES):
        if ch != cin:
            man += [(f"st{s}_down_w", (3, 3, cin, ch)), (f"st{s}_down_b", (ch,))]
            cin = ch
        man += [
            (f"st{s}_c1_w", (3, 3, ch, ch)), (f"st{s}_c1_b", (ch,)),
            (f"st{s}_c2_w", (3, 3, ch, ch)), (f"st{s}_c2_b", (ch,)),
        ]
    man += [("head_w", (CLOUD_STAGES[-1], CLOUD_HEAD_CLASSES)), ("head_b", (CLOUD_HEAD_CLASSES,))]
    return man


def cloud_logits(params, x, *, use_kernels: bool):
    """x (B,32,32,3) -> logits (B,8)."""
    conv = k_conv2d if use_kernels else ref.conv2d
    x = normalize_input(x)
    it = iter(params)
    nxt = lambda: next(it)
    sw, sb = nxt(), nxt()
    h = conv(x, sw, sb, stride=2, act=ref.ACT_RELU)  # 16x16
    cin = CLOUD_STAGES[0]
    for s, ch in enumerate(CLOUD_STAGES):
        if ch != cin:
            dw_, db_ = nxt(), nxt()
            h = conv(h, dw_, db_, stride=2, act=ref.ACT_RELU)  # downsample
            cin = ch
        w1, b1, w2, b2 = nxt(), nxt(), nxt(), nxt()
        r = conv(h, w1, b1, stride=1, act=ref.ACT_RELU)
        r = conv(r, w2, b2, stride=1, act=ref.ACT_NONE)
        h = jnp.maximum(h + r, 0.0)
    feat = jnp.mean(h, axis=(1, 2))
    hw, hb = nxt(), nxt()
    if use_kernels:
        return k_dense(feat, hw, hb, act=ref.ACT_NONE)
    return ref.dense(feat, hw, hb, act=ref.ACT_NONE)


def cloud_forward(params, x, *, use_kernels: bool):
    return softmax(cloud_logits(params, x, use_kernels=use_kernels))


# ---------------------------------------------------------------------------
# Init + (de)serialisation
# ---------------------------------------------------------------------------

def init_params(manifest, seed: int):
    """He-normal init for weights, zeros for biases; deterministic."""
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in manifest:
        if name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])
            std = np.sqrt(2.0 / max(fan_in, 1))
            out.append(jnp.asarray(rng.randn(*shape).astype(np.float32) * std))
    return out


def flatten_params(params) -> np.ndarray:
    return np.concatenate([np.asarray(p, np.float32).ravel() for p in params])


def unflatten_params(flat: np.ndarray, manifest):
    out, off = [], 0
    for _, shape in manifest:
        n = int(np.prod(shape))
        out.append(jnp.asarray(flat[off:off + n].reshape(shape)))
        off += n
    assert off == flat.size, f"param blob size mismatch: {off} != {flat.size}"
    return out
